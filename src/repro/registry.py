"""Component registries: the single place every pluggable DTFL piece is named.

Four PRs grew string-typed knobs all over the codebase — ``TRAINERS`` in
``fed/__init__.py``, scheduler specs parsed inside ``DTFLTrainer.__init__``,
codec specs inside ``core.codec.make_codec``, engine/exec literals in every
entry point — each with its own (or no) validation and its own error wording.
This module migrates them onto one mechanism:

* a :class:`Registry` maps a component *name* (or a parameterized spec such
  as ``dynamic:3`` / ``topk0.05``) to a lazily-imported factory plus static
  metadata, and every unknown name fails with the full legal choice set;
* ``repro.api``'s :class:`~repro.api.ExperimentSpec` validates all of its
  string knobs here **at spec-construction time**, so an invalid combination
  is rejected before any jax import, not deep inside a run;
* registering a new scheduler / codec / trainer / dataset is ~10 lines (see
  ``docs/architecture.md`` §8) and immediately works everywhere — the CLI,
  the benchmark presets, the sweep plane — because they all resolve through
  these tables.

The module is deliberately stdlib-only at import time: argparse-time
validation in ``launch/train.py`` must not pay the jax import. Factories
import their implementation lazily when built.
"""
from __future__ import annotations

import importlib
from typing import Any, Callable


class RegistryError(ValueError):
    """Unknown / duplicate component name (message lists the legal set)."""


class Registry:
    """Name -> (lazy factory, metadata) with parameterized-spec support.

    An entry may carry a ``parse`` callable: given a spec string it returns
    the canonical spec (e.g. ``"topk0.05"`` -> ``"topk0.05"``, ``"none"`` ->
    ``"identity"``) or ``None`` if the spec does not belong to this entry.
    ``pattern`` is the human-readable form shown in error messages.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, dict] = {}

    # -- registration --------------------------------------------------
    def register(self, name: str, **meta: Any) -> None:
        if name in self._entries:
            raise RegistryError(f"{self.kind} {name!r} is already registered")
        self._entries[name] = dict(meta)

    def unregister(self, name: str) -> None:
        self._entries.pop(name, None)

    # -- lookup --------------------------------------------------------
    def names(self) -> list[str]:
        return sorted(self._entries)

    def choices(self) -> list[str]:
        """Display forms for error messages (patterns for parameterized)."""
        return sorted(e.get("pattern", n) for n, e in self._entries.items())

    def __contains__(self, name: str) -> bool:
        try:
            self.resolve(name)
            return True
        except RegistryError:
            return False

    def resolve(self, spec: Any) -> tuple[str, dict]:
        """(canonical spec, entry) for an exact name or parameterized spec."""
        s = str(spec).strip()
        e = self._entries.get(s)
        if e is not None and e.get("parse") is None:
            return s, e
        for name in sorted(self._entries):
            entry = self._entries[name]
            parse = entry.get("parse")
            if parse is None:
                continue
            canon = parse(s)
            if canon is not None:
                return canon, entry
        # note: an exact entry name whose parse rejected it (a bare
        # parameterized family like "topk" or "static") is NOT a legal spec
        raise RegistryError(
            f"unknown {self.kind} {spec!r}; registered {self.kind}s: "
            + ", ".join(self.choices()))

    def validate(self, spec: Any) -> str:
        """Canonical spec string, or RegistryError listing the legal set."""
        return self.resolve(spec)[0]

    def meta(self, spec: Any) -> dict:
        return self.resolve(spec)[1]

    def load(self, spec: Any):
        """Import and return the entry's target class/object."""
        canon, e = self.resolve(spec)
        target = e.get("target")
        if isinstance(target, str):
            mod, _, attr = target.partition(":")
            target = getattr(importlib.import_module(mod), attr)
            e["target"] = target  # cache the resolved class
        return target

    def build(self, spec: Any, **kw):
        """Call the entry's ``build(canonical_spec, **kw)`` factory."""
        canon, e = self.resolve(spec)
        build = e.get("build")
        if build is None:
            raise RegistryError(f"{self.kind} {canon!r} has no build factory")
        return build(canon, **kw)


# ---------------------------------------------------------------------------
# the registries + their public registration helpers
# ---------------------------------------------------------------------------

trainers = Registry("trainer")
schedulers = Registry("scheduler")
codecs = Registry("codec")
engines = Registry("engine")
exec_modes = Registry("exec mode")
datasets = Registry("dataset")
archs = Registry("arch")
profile_pools = Registry("profile pool")
topologies = Registry("topology")


def register_trainer(name: str, target: str | type, *, supports_async: bool = True,
                     supports_codec: bool = True, scheduler_aware: bool = False,
                     **meta: Any) -> None:
    """``target``: ``"module:Class"`` import path (lazy) or the class itself.
    ``supports_async`` / ``supports_codec`` mirror the class attributes so
    spec validation can reject illegal combos without importing jax
    (``tests/test_api.py`` pins registry metadata == class attributes)."""
    trainers.register(name, target=target, supports_async=supports_async,
                      supports_codec=supports_codec,
                      scheduler_aware=scheduler_aware, **meta)


def register_scheduler(name: str, *, build: Callable, parse: Callable | None = None,
                       pattern: str | None = None, **meta: Any) -> None:
    """``build(spec, *, profile, n_clients, n_tiers) -> scheduler``;
    ``parse(spec_str) -> canonical | None`` claims parameterized specs."""
    schedulers.register(name, build=build, parse=parse,
                        pattern=pattern or name, **meta)


def register_codec(name: str, *, build: Callable, parse: Callable | None = None,
                   pattern: str | None = None, identity: bool = False) -> None:
    """``build(spec) -> core.codec.Codec``. ``identity=True`` marks codecs
    that are wire-transparent (legal for trainers with supports_codec=False)."""
    codecs.register(name, build=build, parse=parse, pattern=pattern or name,
                    identity=identity)


def register_engine(name: str, **meta: Any) -> None:
    engines.register(name, **meta)


def register_dataset(name: str, *, kind: str = "image", n_classes: int = 10,
                     noise: float = 0.35, seed: int = 0, **meta: Any) -> None:
    """Image datasets carry the ``ClassImageTask`` knobs (the task's
    image_size always comes from the model config at build time); ``kind=
    "lm"`` marks the token-LM task family for the transformer archs."""
    datasets.register(name, kind=kind, n_classes=n_classes, noise=noise,
                      seed=seed, **meta)


def register_arch(name: str, *, kind: str, build: Callable) -> None:
    """``kind``: "resnet" (image data, ResNetAdapter) or "transformer"
    (token-LM data, TransformerAdapter); ``build() -> full config``."""
    archs.register(name, kind=kind, build=build)


def register_profile_pool(name: str, *, build: Callable) -> None:
    """``build() -> list[timemodel.ResourceProfile]``."""
    profile_pools.register(name, build=build)


# ---------------------------------------------------------------------------
# built-in components (factories import their implementations lazily)
# ---------------------------------------------------------------------------

register_trainer("dtfl", "repro.fed.dtfl:DTFLTrainer", scheduler_aware=True)
register_trainer("fedavg", "repro.fed.fedavg:FedAvgTrainer")
register_trainer("fedyogi", "repro.fed.fedyogi:FedYogiTrainer", supports_async=False)
register_trainer("splitfed", "repro.fed.splitfed:SplitFedTrainer", supports_codec=False)
register_trainer("fedgkt", "repro.fed.fedgkt:FedGKTTrainer",
                 supports_async=False, supports_codec=False)
register_trainer("tifl", "repro.fed.tifl:TiFLTrainer", supports_async=False)
register_trainer("drop30", "repro.fed.dropstrag:DropStragglerTrainer",
                 supports_async=False)
register_trainer("fedat", "repro.fed.fedat:FedATTrainer", async_native=True)


def _parse_dynamic(s: str) -> str | None:
    if s == "dynamic":
        return s
    if s.startswith("dynamic:"):
        try:
            m = int(s.split(":", 1)[1])
        except ValueError:
            return None
        return s if m >= 1 else None
    return None


def _build_dynamic(spec: str, *, profile, n_clients: int, n_tiers: int):
    from repro.core.scheduler import DynamicTierScheduler

    if spec == "dynamic":
        return DynamicTierScheduler(profile, n_clients)
    m = int(spec.split(":", 1)[1])  # M-tier deployment (paper Table 11)
    allowed = list(range(n_tiers))[-m:]
    return DynamicTierScheduler(profile, n_clients, allowed=allowed)


def _parse_static(s: str) -> str | None:
    try:
        return str(int(s)) if int(s) >= 0 else None
    except ValueError:
        return None


def _build_static(spec: str, *, profile, n_clients: int, n_tiers: int):
    from repro.core.scheduler import StaticScheduler

    return StaticScheduler(int(spec), n_clients)


def _parse_pairing(s: str) -> str | None:
    if s == "pairing" or s == "pairing:hungarian":
        return "pairing"
    if s == "pairing:greedy":
        return s
    return None


def _build_pairing(spec: str, *, profile, n_clients: int, n_tiers: int):
    from repro.core.scheduler import PairingScheduler

    method = spec.split(":", 1)[1] if ":" in spec else "hungarian"
    return PairingScheduler(profile, n_clients, method=method)


register_scheduler("dynamic", build=_build_dynamic, parse=_parse_dynamic,
                   pattern="dynamic | dynamic:<M>")
register_scheduler("static", build=_build_static, parse=_parse_static,
                   pattern="<fixed tier index, e.g. 0>")
register_scheduler("pairing", build=_build_pairing, parse=_parse_pairing,
                   pattern="pairing | pairing:greedy", provides_hosts=True)

# Offload topologies (core/topology.py): who executes a client's far half.
# ``scheduler`` names the scheduler family that produces the required
# assignment shape; spec validation (api.py) keeps the two fields coherent.
topologies.register("server", scheduler=None,
                    doc="classic DTFL: every far half runs on the server")
topologies.register("pairing", scheduler="pairing",
                    doc="mutual offload: fast clients host slow clients' "
                        "far halves (arxiv 2308.13849)")


def _codec_build(cls_name: str):
    def build(spec: str):
        import repro.core.codec as codec_lib

        cls = getattr(codec_lib, cls_name)
        if cls_name == "TopKCodec":
            return cls(float(spec[4:].lstrip(":")))
        return cls()

    return build


def _parse_identity(s: str) -> str | None:
    return "identity" if s in ("identity", "none", "") else None


def _parse_topk(s: str) -> str | None:
    if not s.startswith("topk"):
        return None
    try:
        frac = float(s[4:].lstrip(":"))
    except ValueError:
        return None
    return s if 0.0 < frac <= 1.0 else None


register_codec("identity", build=_codec_build("IdentityCodec"),
               parse=_parse_identity, identity=True)
register_codec("bf16", build=_codec_build("Bf16Codec"))
register_codec("int8", build=_codec_build("Int8Codec"))
register_codec("topk", build=_codec_build("TopKCodec"), parse=_parse_topk,
               pattern="topk<frac> (e.g. topk0.05)")

register_engine("rounds", sync=True)
register_engine("events", sync=True)
register_engine("async", sync=False)

for _m in ("loop", "cohort", "sharded", "chunked"):
    exec_modes.register(_m)

# the paper's four image benchmarks (data/synthetic.DATASETS) + the noisier
# variants the Table-1/Table-5 protocols train on, + the token-LM family
register_dataset("cifar10", n_classes=10)
register_dataset("cifar100", n_classes=100)
register_dataset("cinic10", n_classes=10, noise=0.5, seed=1)
register_dataset("ham10000", n_classes=7, seed=2)
register_dataset("cifar10-hard", n_classes=10, noise=0.6)    # Table 1 protocol
register_dataset("cifar10-noisy", n_classes=10, noise=1.0)   # Table 5 protocol
register_dataset("lm", kind="lm")


def _resnet_arch(name: str):
    def build(spec: str):
        from repro.configs.resnet_cifar import get_resnet

        return get_resnet(name)

    return build


def _transformer_arch(name: str):
    def build(spec: str):
        from repro.configs import get_config

        return get_config(name)

    return build


for _n in ("resnet-56", "resnet-110", "resnet-bench", "resnet-micro"):
    register_arch(_n, kind="resnet", build=_resnet_arch(_n))

# the assigned transformer pool (mirrors repro.configs.ASSIGNED_ARCHS; static
# so argparse-time validation stays jax-free — pinned by tests/test_api.py)
ASSIGNED_ARCH_NAMES = (
    "whisper-base", "granite-3-2b", "pixtral-12b", "yi-6b", "xlstm-350m",
    "hymba-1.5b", "deepseek-moe-16b", "deepseek-67b", "llama4-scout-17b-a16e",
    "smollm-360m",
)
for _n in ASSIGNED_ARCH_NAMES:
    register_arch(_n, kind="transformer", build=_transformer_arch(_n))


def _pool(attr: str | None):
    def build(spec: str):
        from repro.core import timemodel

        if attr is None:  # the paper's most bandwidth-starved class
            return [timemodel.ResourceProfile(0.1, 10.0)]
        return list(getattr(timemodel, attr))

    return build


register_profile_pool("paper", build=_pool("PAPER_PROFILES"))
register_profile_pool("case1", build=_pool("CASE1_PROFILES"))
register_profile_pool("case2", build=_pool("CASE2_PROFILES"))
register_profile_pool("slow10mbps", build=_pool(None))
