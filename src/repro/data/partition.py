"""Federated data partitioning: IID and Dirichlet label-skew non-IID.

The paper (Appendix A.4) uses a Dirichlet distribution with concentration
0.5 and a fixed seed; Table 7 shows the resulting per-client label counts.
"""
from __future__ import annotations

import numpy as np


def iid_partition(labels: np.ndarray, n_clients: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(part) for part in np.array_split(idx, n_clients)]


def dirichlet_partition(
    labels: np.ndarray, n_clients: int, alpha: float = 0.5, seed: int = 0,
    min_size: int = 2, max_tries: int = 100,
) -> list[np.ndarray]:
    """Label-skew partition: for each class, split its samples across clients
    with Dirichlet(alpha) proportions (He et al. 2020b / paper A.4).

    The ``min_size`` rejection loop is bounded: each attempt reseeds
    deterministically (attempt 0 draws exactly what an unbounded loop's
    first pass drew, so existing partitions are unchanged), and after
    ``max_tries`` failures a clear error replaces the old infinite spin —
    with few samples or many clients the constraint can be unsatisfiable.
    """
    if n_clients * min_size > len(labels):
        raise ValueError(
            f"dirichlet_partition: {n_clients} clients x min_size {min_size} "
            f"needs >= {n_clients * min_size} samples, got {len(labels)}")
    n_classes = int(labels.max()) + 1
    for attempt in range(max_tries):
        rng = np.random.default_rng(seed + 1_000_003 * attempt)
        parts: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for k, chunk in enumerate(np.split(idx_c, cuts)):
                parts[k].extend(chunk.tolist())
        if min(len(p) for p in parts) >= min_size:
            return [np.sort(np.array(p)) for p in parts]
    raise ValueError(
        f"dirichlet_partition: no partition with min_size={min_size} after "
        f"{max_tries} attempts (n={len(labels)}, n_clients={n_clients}, "
        f"alpha={alpha}); lower min_size/n_clients or raise max_tries")


def label_histogram(labels: np.ndarray, parts: list[np.ndarray]) -> np.ndarray:
    n_classes = int(labels.max()) + 1
    return np.stack([np.bincount(labels[p], minlength=n_classes) for p in parts])
