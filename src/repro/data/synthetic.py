"""Synthetic datasets (the container is offline — DESIGN.md §8).

Two task families:

* ``ClassImageTask`` — CIFAR-shaped classification: each class has a fixed
  random template image; samples are template + Gaussian noise. Learnable by
  the paper's ResNets; "accuracy" targets in the benchmarks are defined on
  this task. Mirrors CIFAR-10/100/CINIC-10/HAM10000 by (n_classes, size).

* ``SeqTask`` — token LM task for the transformer archs: a fixed random
  ngram-ish transition table generates token streams with learnable
  next-token structure.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ClassImageTask:
    n_classes: int = 10
    image_size: int = 32
    noise: float = 0.35
    seed: int = 0

    def templates(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.normal(0, 1, (self.n_classes, self.image_size, self.image_size, 3)).astype(
            np.float32
        )

    def sample(self, labels: np.ndarray, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        t = self.templates()[labels]
        return (t + rng.normal(0, self.noise, t.shape)).astype(np.float32)


# named dataset variants matching the paper's four benchmarks
DATASETS = {
    "cifar10": ClassImageTask(n_classes=10),
    "cifar100": ClassImageTask(n_classes=100),
    "cinic10": ClassImageTask(n_classes=10, noise=0.5, seed=1),     # harder/noisier
    "ham10000": ClassImageTask(n_classes=7, image_size=32, seed=2),
}


@dataclass(frozen=True)
class SeqTask:
    vocab: int
    order: int = 2
    seed: int = 0

    def stream(self, n_tokens: int, seed: int) -> np.ndarray:
        """Deterministic-ish Markov stream: next = f(prev tokens) + noise."""
        rng = np.random.default_rng(self.seed)
        a = rng.integers(1, self.vocab, self.order)
        b = rng.integers(0, self.vocab)
        out = np.zeros(n_tokens + self.order, np.int64)
        out[: self.order] = rng.integers(0, self.vocab, self.order)
        noise_rng = np.random.default_rng(seed)
        noise = noise_rng.random(n_tokens) < 0.1
        rand_tok = noise_rng.integers(0, self.vocab, n_tokens)
        for t in range(n_tokens):
            nxt = (int(np.dot(a, out[t : t + self.order])) + b) % self.vocab
            out[t + self.order] = rand_tok[t] if noise[t] else nxt
        return out[self.order :].astype(np.int32)

    def batches(self, batch: int, seq: int, n_batches: int, seed: int = 0):
        for i in range(n_batches):
            s = self.stream(batch * (seq + 1), seed * 10_000 + i)
            s = s.reshape(batch, seq + 1)
            yield {"tokens": s[:, :-1], "labels": s[:, 1:]}
