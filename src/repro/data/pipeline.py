"""Client-local data pipeline: label pools -> shuffled minibatches.

A ``ClientDataset`` owns a client's partition indices, materializes samples
lazily per batch (templates + noise are regenerated deterministically from
the epoch seed, so no dataset-sized arrays are held), and yields dict batches
compatible with the training steps.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import ClassImageTask

# Deterministic per-round epoch seeding shared by the sequential loop and the
# cohort engine: epoch e of round r draws from seed r * ROUND_SEED_STRIDE + e,
# so both execution paths consume bit-identical batches.
ROUND_SEED_STRIDE = 131


def materialize_round(dataset, r: int, local_epochs: int) -> dict:
    """All of a client's local steps for round ``r`` as stacked arrays.

    Works for any dataset exposing ``epoch(epoch_seed)``; returns a dict of
    (n_steps, batch, ...) arrays with n_steps = local_epochs * n_batches.
    """
    steps = [
        batch
        for e in range(local_epochs)
        for batch in dataset.epoch(r * ROUND_SEED_STRIDE + e)
    ]
    return {k: np.stack([s[k] for s in steps]) for k in steps[0]}


class ClientDataset:
    """Batches are FIXED-SHAPE: a client with fewer than ``batch_size``
    samples (common under Dirichlet non-IID) pads its one batch up to
    ``batch_size`` with zero samples and carries a per-sample ``mask``
    (1 real / 0 pad) that the losses honor (core/local_loss.py:
    ``token_xent(..., weight=)``). Without the padding, every odd partial
    shape became its own (tier, shape) cohort compile and defeated the
    sharded plane's padding."""

    def __init__(self, task: ClassImageTask, labels: np.ndarray, indices: np.ndarray,
                 batch_size: int, seed: int = 0):
        self.task = task
        self.labels = labels
        self.indices = indices
        self.batch_size = batch_size
        self.seed = seed

    def __len__(self) -> int:
        return len(self.indices)

    @property
    def n_batches(self) -> int:
        return max(1, len(self.indices) // self.batch_size)

    def epoch(self, epoch_seed: int):
        rng = np.random.default_rng(self.seed * 100_003 + epoch_seed)
        order = rng.permutation(self.indices)
        for i in range(self.n_batches):
            sel = order[i * self.batch_size : (i + 1) * self.batch_size]
            if len(sel) == 0:
                break
            y = self.labels[sel]
            x = self.task.sample(y, seed=int(rng.integers(1 << 31)))
            mask = np.ones(self.batch_size, np.float32)
            if len(sel) < self.batch_size:
                pad = self.batch_size - len(sel)
                x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
                y = np.concatenate([y, np.zeros(pad, y.dtype)])
                mask[len(sel):] = 0.0
            yield {"images": x, "labels": y.astype(np.int32), "mask": mask}


def make_eval_batch(task: ClassImageTask, n: int, seed: int = 1234) -> dict:
    rng = np.random.default_rng(seed)
    y = rng.integers(0, task.n_classes, n)
    x = task.sample(y, seed=seed + 1)
    return {"images": x, "labels": y.astype(np.int32)}


class SeqClientDataset:
    """Token-LM per-client dataset with the ClientDataset interface."""

    def __init__(self, task, n_batches: int, batch_size: int, seq: int, seed: int):
        self.task, self._n, self.batch_size, self.seq, self.seed = task, n_batches, batch_size, seq, seed

    def __len__(self):
        return self._n * self.batch_size

    @property
    def n_batches(self):
        return self._n

    def epoch(self, epoch_seed: int):
        yield from self.task.batches(self.batch_size, self.seq, self._n,
                                     seed=self.seed * 7919 + epoch_seed)
