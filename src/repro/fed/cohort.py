"""Tier-cohort vectorized round engine.

The sequential round loop dispatches O(n_clients x n_batches) jitted steps
per round; this engine collapses each round to O(n_tiers) device programs:

  1. participants are grouped into *cohorts* by (tier, per-batch sample
     shape) — every client in a cohort trains the same client/server split
     on identically-shaped batches;
  2. each client's local steps (``local_epochs`` epochs of its minibatches)
     are materialized and stacked into leading-axis arrays of shape
     ``(n_steps, n_clients, batch, ...)``;
  3. ragged cohorts (clients with unequal batch counts) are padded with
     zero batches up to the cohort max and masked out: a ``(n_steps,
     n_clients)`` boolean mask gates the state update, so padded steps are
     identity for that client;
  4. one jitted program per cohort runs ``jax.lax.scan`` over steps with a
     ``jax.vmap``-ed per-client step inside, so XLA sees a single static
     (n_steps, n_clients)-shaped computation per (tier, shape-bucket).

The engine is trainer-agnostic: any per-client step function
``step(state, batch) -> (state, out)`` over arbitrary pytrees can be lifted
with :func:`run_cohort`. ``DTFLTrainer`` uses it for per-tier split
training; ``BaseTrainer`` routes the full-model baselines (FedAvg, TiFL,
SplitFed, FedYogi, DropStrag) through the same path.

Recompilation note: a cohort program specializes on (n_steps, n_clients,
batch shapes). Rounds with stable tier assignments and participation reuse
the cached executable; a changed cohort size retraces.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import materialize_round


@dataclass
class Cohort:
    """One (tier, batch-shape) group of a round's participants."""

    tier: int
    cids: list[int]                # participant ids, stacking order
    batches: dict                  # name -> (n_steps, n_clients + n_pad, batch, ...)
    mask: np.ndarray               # (n_steps, n_clients + n_pad) bool; False = padded
    n_pad: int = 0                 # trailing pad clients (sharded divisibility)

    @property
    def size(self) -> int:
        return len(self.cids)

    def client_weights(self, clients) -> np.ndarray:
        """(size + n_pad,) f32 aggregation weights: N_k for real members, 0
        for pad clients — so on-device weighted sums ignore padding exactly."""
        w = [float(len(clients[k].dataset)) for k in self.cids] + [0.0] * self.n_pad
        return np.asarray(w, np.float32)


def build_cohorts(
    clients, cids: list[int], tier_of: dict[int, int], r: int, local_epochs: int,
    *, pad_multiple: int = 1,
) -> list[Cohort]:
    """Group ``cids`` into cohorts and stack their round-``r`` batches.

    ``tier_of`` maps cid -> tier (use a constant for untired full-model
    training). Batches come from ``materialize_round`` so they are
    bit-identical to what the sequential loop would consume.

    ``pad_multiple > 1`` (the sharded plane's mesh axis size) pads each
    cohort's client axis with zero-batch / all-False-mask / weight-0 pad
    clients up to the next multiple, so ``shard_map`` can split the axis
    evenly; pad clients never touch state (mask) or aggregation (weight).
    """
    per_client = {k: materialize_round(clients[k].dataset, r, local_epochs) for k in cids}
    groups: dict[tuple, list[int]] = {}
    for k in cids:
        arrs = per_client[k]
        shape_key = tuple(sorted((name, a.shape[1:]) for name, a in arrs.items()))
        groups.setdefault((tier_of[k], shape_key), []).append(k)

    cohorts = []
    for (tier, _), members in groups.items():
        steps = np.array([len(next(iter(per_client[k].values()))) for k in members])
        s_max = int(steps.max())
        n_pad = (-len(members)) % max(1, int(pad_multiple))
        names = per_client[members[0]].keys()
        batches = {}
        for name in names:
            stacked = np.stack(
                [_pad_steps(per_client[k][name], s_max) for k in members], axis=1
            )  # (S, C, batch, ...)
            if n_pad:
                zeros = np.zeros(
                    (s_max, n_pad) + stacked.shape[2:], stacked.dtype
                )
                stacked = np.concatenate([stacked, zeros], axis=1)
            batches[name] = stacked
        steps_padded = np.concatenate([steps, np.zeros(n_pad, steps.dtype)])
        mask = np.arange(s_max)[:, None] < steps_padded[None, :]  # (S, C + pad)
        cohorts.append(Cohort(tier, members, batches, mask, n_pad))
    return cohorts


def chunk_slices(n_cols: int, chunk_size: int) -> list[slice]:
    """Client-axis slices cutting a padded cohort into fixed-size chunks.

    The chunked ExecPlan builds cohorts with ``pad_multiple=chunk_size``, so
    ``n_cols`` (real + pad clients) is always divisible and every chunk has
    the same static shape — one compiled per-chunk program serves them all.
    """
    if n_cols % chunk_size:
        raise ValueError(
            f"cohort client axis {n_cols} is not a multiple of chunk_size "
            f"{chunk_size}; build cohorts with pad_multiple=chunk_size")
    return [slice(i, i + chunk_size) for i in range(0, n_cols, chunk_size)]


def slice_clients(batches: dict, mask: np.ndarray, sl: slice) -> tuple[dict, np.ndarray]:
    """One client-chunk's view of a cohort's stacked batches + step mask."""
    return {k: v[:, sl] for k, v in batches.items()}, mask[:, sl]


def _pad_steps(a: np.ndarray, s_max: int) -> np.ndarray:
    if len(a) == s_max:
        return a
    pad = np.zeros((s_max - len(a),) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad])


# ---------------------------------------------------------------------------
# the vectorized program
# ---------------------------------------------------------------------------

def broadcast_state(state, n: int):
    """Replicate a single-client state pytree along a new leading axis."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + jnp.shape(x)), state)


def tree_select(mask: jax.Array, new, old):
    """Per-client select: leaves have leading client axis; mask is (C,)."""
    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (jnp.ndim(n) - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(sel, new, old)


def run_cohort(step_fn, state, batches, mask):
    """Traceable core: broadcast a SINGLE client's initial ``state`` across
    the cohort and scan ``step(state, batch) -> (state, out)`` over the
    stacked steps with a vmapped per-client step inside. Masked (padded)
    steps leave that client's state untouched.

    Call inside a jitted per-trainer program so that state construction
    (split, optimizer init) and post-processing (merge, weighted sums) fuse
    into the same device program — eager dispatch is the cost the engine
    exists to remove.
    """
    stacked = broadcast_state(state, mask.shape[1])

    def body(s, xs):
        batch, m = xs
        new_s, out = jax.vmap(step_fn)(s, batch)
        return tree_select(m, new_s, s), out

    return jax.lax.scan(body, stacked, (batches, mask))
