"""SplitFed (Thapa et al. 2022): split learning + federated aggregation.

The model is split at a FIXED point (the paper uses module md2). Unlike
DTFL's local-loss training, gradients DO flow server->client, so each batch
is a synchronous round trip:

  client fwd -> upload z -> server fwd+bwd -> download grad_z -> client bwd

The math equals ordinary backprop through the full model (we compute it as
one jitted step); the cost model charges the sequential path
(``client_time``), which is what makes SplitFed slow in the paper's Table 3.
"""
from __future__ import annotations

from repro.fed.base import BaseTrainer

SPLIT_TIER = 1  # 0-based: client keeps md1..md2, the paper's SplitFed split


class SplitFedTrainer(BaseTrainer):
    name = "splitfed"
    # the per-batch z-up/grad-down gradient round trip is NOT the codec
    # plane's download/update-upload contract; compressing grad_z would
    # change the backprop math, so non-identity codecs are rejected
    supports_codec = False

    def client_time(self, k: int) -> float:
        return self._splitfed_time(k, self.clients[k].n_batches)

    def _splitfed_time(self, cid: int, nb: int) -> float:
        prof = self.env.profile(cid)
        m = SPLIT_TIER
        c_fwd = self.costs.client_flops[m] / 3.0          # fwd is ~1/3 of fwd+bwd
        c_bwd = self.costs.client_flops[m] * 2.0 / 3.0
        per_batch = (
            c_fwd / prof.flops
            + self.costs.z_bytes[m] / prof.bytes_per_s          # z up
            + self.costs.server_flops[m] / self.server_flops    # server fwd+bwd
            + self.costs.z_bytes[m] / prof.bytes_per_s          # grad_z down
            + c_bwd / prof.flops
        )
        model_sync = 2.0 * self.costs.client_param_bytes[m] / prof.bytes_per_s
        return nb * self.local_epochs * per_batch + model_sync
