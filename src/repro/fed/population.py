"""Million-client population plane: lazy client-state store + lazy env.

The dense construction path materializes every registered client up front —
a ``SimClient`` list, a profile-assignment array, a scheduler state list —
which caps the registry at ~10^3 clients. TiFL (arXiv:2001.09249) and FedAT
(arXiv:2010.05958) frame tiered FL as sampling 10^2-10^4 participants per
round out of a far larger registry; this module makes that regime cheap:

* :class:`ClientStore` — a lazy, sequence-like registry of ``n`` clients.
  A ``SimClient`` is built by the ``factory`` on FIRST access and cached;
  a never-sampled client allocates nothing. ``compact(keep)`` drops cached
  entries of clients that permanently left the federation.
* :class:`LazyHeteroEnv` — the :class:`~repro.fed.client.HeteroEnv`
  interface with O(1) memory and O(touched) state. Profiles are drawn
  deterministically from ``(seed, cid)``; ``maybe_switch`` records the
  switch ROUND instead of re-rolling an assignment array, and a client's
  profile is resolved lazily by replaying the switch draws for its id.

Everything is a pure function of ``(seed, cid)`` plus a small event log, so
checkpoints serialize only the touched state (the registry itself needs no
serialization beyond the spec's seed) and resume stays bit-deterministic.

Memory model: peak host memory is O(touched clients) = O(sampled
participants x rounds), never O(population). ``benchmarks/table4_scaling.py``
pins this with a 100k-registry / 512-sample regime.
"""
from __future__ import annotations

import numpy as np

from repro.core.timemodel import PAPER_PROFILES, ResourceProfile


def cid_rng(seed: int, tag: int, *parts: int) -> np.random.Generator:
    """Deterministic per-(seed, cid, ...) stream, independent across tags."""
    return np.random.default_rng([int(seed), int(tag), *map(int, parts)])


class ClientStore:
    """Lazy sequence of ``SimClient``s: ``factory(cid)`` runs on first access.

    Quacks like the ``list[SimClient]`` the trainers were built on
    (``len``, ``[]``, iteration), so ``fed/base.py`` / ``fed/dtfl.py`` /
    ``fed/cohort.py`` consume it unchanged. Iterating materializes every
    client — fine for test-sized registries, never done by the engines.
    """

    def __init__(self, n: int, factory):
        if n < 1:
            raise ValueError(f"ClientStore needs n >= 1, got {n}")
        self._n = int(n)
        self._factory = factory
        self._cache: dict[int, object] = {}

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, cid: int):
        cid = int(cid)
        if not 0 <= cid < self._n:
            raise IndexError(f"client id {cid} out of range [0, {self._n})")
        cl = self._cache.get(cid)
        if cl is None:
            cl = self._cache[cid] = self._factory(cid)
        return cl

    def __iter__(self):
        for cid in range(self._n):
            yield self[cid]

    # ------------------------------------------------------------------
    @property
    def n_touched(self) -> int:
        return len(self._cache)

    def touched(self) -> list[int]:
        """Client ids materialized so far (sorted)."""
        return sorted(self._cache)

    def compact(self, keep) -> None:
        """Drop cached clients outside ``keep`` (permanent departures).

        Lazy reconstruction makes this lossless for the *data* plane — a
        compacted client that returns is rebuilt bit-identically from the
        factory. Trainer-held per-client state (EF residuals, scheduler
        history) is compacted by ``BaseTrainer.compact``, which owns the
        never-drop-a-live-client invariant.
        """
        keep = set(int(k) for k in keep)
        self._cache = {c: v for c, v in self._cache.items() if c in keep}


class LazyHeteroEnv:
    """``HeteroEnv`` semantics with O(1) construction and O(touched) state.

    The dense env materializes an ``assignment`` array (even profile split,
    shuffled) and re-rolls a random 30% of it every ``switch_every`` rounds.
    Here a client's base profile is an independent uniform draw from
    ``(seed, cid)`` — the even split holds in expectation — and each switch
    round ``rs`` re-rolls client ``cid`` iff its ``(seed, rs, cid)`` draw
    lands under ``switch_frac``; ``maybe_switch`` only APPENDS the round to
    the switch log, so it is O(1) regardless of population.

    ``set_profile`` (mid-round churn) pins an override; later switch rounds
    may re-roll it, matching the dense env's point-mutation semantics.
    Resolved profiles are cached per touched cid and invalidated when the
    switch log grows.
    """

    def __init__(
        self,
        n_clients: int,
        profiles: list[ResourceProfile] | None = None,
        *,
        switch_every: int = 50,
        switch_frac: float = 0.3,
        seed: int = 0,
    ):
        self.profiles = profiles or PAPER_PROFILES
        self.n_clients = int(n_clients)
        self.switch_every = switch_every
        self.switch_frac = switch_frac
        self.seed = int(seed)
        self._switch_rounds: list[int] = []       # applied switch rounds, ordered
        self._switched_rounds: set[int] = set()   # guard (async multi-group calls)
        # cid -> (switch-log position the override was set at, profile idx)
        self._overrides: dict[int, tuple[int, int]] = {}
        self._cache: dict[int, int] = {}          # cid -> resolved idx
        self._version = 0                         # invalidates _cache

    # -- HeteroEnv interface -------------------------------------------
    def maybe_switch(self, round_idx: int) -> None:
        if (self.switch_every and round_idx > 0
                and round_idx % self.switch_every == 0
                and round_idx not in self._switched_rounds):
            self._switched_rounds.add(round_idx)
            self._switch_rounds.append(round_idx)
            self._cache.clear()
            self._version += 1

    def set_profile(self, cid: int, profile_idx: int) -> None:
        self._overrides[int(cid)] = (len(self._switch_rounds), int(profile_idx))
        self._cache.pop(int(cid), None)

    def profile(self, cid: int) -> ResourceProfile:
        return self.profiles[self.profile_idx(cid)]

    def profile_idx(self, cid: int) -> int:
        cid = int(cid)
        idx = self._cache.get(cid)
        if idx is None:
            idx = self._cache[cid] = self._resolve(cid)
        return idx

    def _resolve(self, cid: int) -> int:
        ov = self._overrides.get(cid)
        if ov is not None:
            pos, idx = ov
        else:
            pos = 0
            idx = int(cid_rng(self.seed, 11, cid).integers(len(self.profiles)))
        for rs in self._switch_rounds[pos:]:
            r = cid_rng(self.seed, 13, rs, cid)
            if r.random() < self.switch_frac:
                idx = int(r.integers(len(self.profiles)))
        return idx

    @property
    def n_touched(self) -> int:
        """Clients with resolved-profile or override state (memory proxy)."""
        return len(self._cache) + len(self._overrides)

    # -- resumable state (sparse: the event log, never the population) --
    def save_state(self) -> dict:
        ov = sorted(self._overrides.items())
        return {
            "lazy": np.int64(1),
            "switch_rounds": np.array(self._switch_rounds, dtype=np.int64),
            "ov_cids": np.array([c for c, _ in ov], dtype=np.int64),
            "ov_pos": np.array([p for _, (p, _) in ov], dtype=np.int64),
            "ov_idx": np.array([i for _, (_, i) in ov], dtype=np.int64),
        }

    def load_state(self, state: dict) -> None:
        if "lazy" not in state:
            raise ValueError(
                "checkpoint env state is the dense HeteroEnv format; it "
                "cannot resume a population-mode (lazy env) run")
        self._switch_rounds = [int(r) for r in
                               np.asarray(state["switch_rounds"]).reshape(-1)]
        self._switched_rounds = set(self._switch_rounds)
        self._overrides = {
            int(c): (int(p), int(i))
            for c, p, i in zip(np.asarray(state["ov_cids"]).reshape(-1),
                               np.asarray(state["ov_pos"]).reshape(-1),
                               np.asarray(state["ov_idx"]).reshape(-1))
        }
        self._cache.clear()
        self._version += 1
