"""Shared machinery for the baseline federated trainers (paper Sec. 4.1).

All baselines consume the same adapter / clients / env / synthetic clock as
DTFLTrainer so Table-3 style comparisons are apples-to-apples: identical
model, partitions, eval batch; only the algorithm and its time profile vary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, timemodel
from repro.core import codec as codec_lib
from repro.data import pipeline
from repro.fed import cohort as cohort_engine
from repro.fed import engine as event_engine
from repro.fed.client import HeteroEnv, SimClient
from repro.fed.engine import RoundLog, RoundPlan
from repro.fed.execplan import ExecPlan


def kd_loss(student_logits: jax.Array, teacher_logits: jax.Array, temp: float = 1.0,
            weight: jax.Array | None = None) -> jax.Array:
    """KL(teacher || student) with temperature. ``weight`` (per-sample, e.g.
    the fixed-shape pad mask from data/pipeline.py) turns the mean over rows
    into a weighted mean so padded samples contribute nothing."""
    t = jax.nn.softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    ls = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temp, -1)
    lt = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    per = jnp.sum(t * (lt - ls), -1)
    if weight is None:
        return jnp.mean(per) * temp * temp
    w = weight.astype(jnp.float32)
    w = jnp.broadcast_to(w.reshape(w.shape + (1,) * (per.ndim - w.ndim)), per.shape)
    return jnp.sum(per * w) / jnp.maximum(jnp.sum(w), 1.0) * temp * temp


class BaseTrainer:
    """Round loop scaffolding; subclasses implement train_round()."""

    name = "base"
    # whether the async engine's default train_group (plain FedAvg-style
    # group aggregation) faithfully represents this algorithm. Trainers
    # whose algorithm lives in execute_round / select_clients (fedyogi's
    # server optimizer, fedgkt's KD phases, tifl/drop30's selection) must
    # NOT silently degrade to FedAvg under engine="async".
    supports_async = True
    # whether the codec plane's wires map onto this algorithm's round
    # structure. SplitFed's per-batch gradient round-trip and FedGKT's
    # bespoke two-phase KD protocol are NOT the download/upload wires the
    # codec contract compresses, so they reject non-identity codecs rather
    # than silently mis-pricing them.
    supports_codec = True

    def __init__(self, adapter, clients: list[SimClient], env: HeteroEnv, optimizer,
                 *, seed: int = 0, local_epochs: int = 1,
                 server_flops: float = timemodel.SERVER_FLOPS,
                 exec_plan: ExecPlan | str | None = None,
                 codec: codec_lib.Codec | str | None = None):
        self.adapter = adapter
        self.clients = clients
        self.env = env
        self.opt = optimizer
        self.local_epochs = local_epochs
        self.server_flops = server_flops
        # "loop" | "cohort" | "sharded[mesh]" — replaces the old cohort bool
        self.exec_plan = ExecPlan.resolve(exec_plan)
        self.key = jax.random.PRNGKey(seed)
        self.params = adapter.init_global(self._next_key())
        self.costs = adapter.tier_costs(clients[0].dataset.batch_size)
        # communication plane (core/codec.py): download the codec'd global,
        # upload codec'd deltas, price both wires with codec-true bytes
        self.codec = codec_lib.make_codec(codec)
        if not self.supports_codec and not self.codec.is_identity:
            raise ValueError(
                f"{self.name} does not support wire compression (codec="
                f"{self.codec.name!r}); its round structure is not the "
                "download/update-upload contract the codec plane compresses")
        self.wires = codec_lib.wire_sizes(self.costs, self.codec)
        self._ef: dict[int, dict] = {}     # cid -> error-feedback residual
        self.last_uplink_bytes = 0.0

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------
    # engine hooks (fed/engine.py contract). Full-model baselines override
    # select_clients / client_time / execute_round / observe_round; the
    # defaults implement FedAvg semantics.
    # ------------------------------------------------------------------
    def select_clients(self, r: int, participants: list[int]) -> list[int]:
        """Which participants actually train (TiFL picks a tier, drop30 the
        fastest subset)."""
        return list(participants)

    def client_time(self, k: int) -> float:
        """Planned Eq.-5 completion offset for client ``k`` under this
        algorithm's time profile."""
        return self._full_model_time(k, self.clients[k].n_batches)

    def plan_round(self, r: int, participants: list[int]) -> RoundPlan:
        self.env.maybe_switch(r)
        trained = list(self.select_clients(r, participants))
        times = np.array([self.client_time(k) for k in trained], float)
        # full-model uplink = one codec'd update upload per trained client
        self.last_uplink_bytes = float(self.wires.full_up * len(trained))
        return RoundPlan(
            participants=list(participants), trained=trained,
            assign={k: 0 for k in trained}, times=times,
        )

    def execute_round(self, r: int, plan: RoundPlan, trained: list[int]) -> float:
        """Train the survivors; returns extra serial time (FedGKT's server
        phase) appended after the last completion."""
        if trained:
            self.params = self._train_round_full(r, trained)
        return 0.0

    def observe_round(self, plan: RoundPlan, idx: list[int], obs_times, totals) -> None:
        """Feed event-derived timestamps back (TiFL's speed profiling)."""

    def train_group(self, r: int, plan: RoundPlan, trained: list[int]):
        """Async-tier hook: group aggregate without committing to params."""
        tree = self._train_round_full(r, trained)
        return tree, float(sum(len(self.clients[k].dataset) for k in trained))

    def async_groups(self, cids: list[int], n_groups: int) -> list[list[int]]:
        """Speed groups (fast -> slow) by this algorithm's own time profile —
        the FedAT/TiFL tier-profiling step."""
        return event_engine.split_speed_groups(
            sorted(cids, key=self.client_time), n_groups
        )

    # ------------------------------------------------------------------
    def train_round(self, r: int, participants: list[int]) -> float:
        """Legacy scalar-clock round: plan -> execute(all) -> observe(all)."""
        plan = self.plan_round(r, participants)
        extra = self.execute_round(r, plan, plan.trained)
        self.observe_round(
            plan, list(range(len(plan.trained))), plan.times, plan.times
        )
        return float(plan.times.max()) + extra

    # ------------------------------------------------------------------
    # error-feedback state (stateful codecs): one full-model-shaped
    # residual per client, host-side
    # ------------------------------------------------------------------
    def _client_ef(self, cid: int):
        st = self._ef.get(cid)
        if st is not None:
            return st
        return jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), self.params)

    def _gather_ef_cids(self, cids, *, pad_to: int | None = None):
        trees = [self._client_ef(k) for k in cids]
        n_pad = 0 if pad_to is None else pad_to - len(trees)
        if n_pad:
            z = (jax.tree.map(np.zeros_like, trees[0]) if trees
                 else jax.tree.map(
                     lambda x: np.zeros(x.shape, x.dtype), self.params))
            trees += [z] * n_pad
        return jax.tree.map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *trees)

    def _scatter_ef_cids(self, cids, ef) -> None:
        for i, cid in enumerate(cids):
            self._ef[cid] = jax.tree.map(lambda x: np.asarray(x[i]), ef)

    def _gather_ef(self, co):
        return self._gather_ef_cids(co.cids, pad_to=co.size + co.n_pad)

    def _scatter_ef(self, co, ef) -> None:
        self._scatter_ef_cids(co.cids, ef)

    # ------------------------------------------------------------------
    def compact(self, keep) -> None:
        """Drop per-client state (cached data clients, EF residuals) of
        clients outside ``keep`` — PERMANENT departures only; the engines
        never call this (transiently-offline churn clients keep state)."""
        keep = set(int(k) for k in keep)
        if hasattr(self.clients, "compact"):
            self.clients.compact(keep)
        self._ef = {c: st for c, st in self._ef.items() if c in keep}

    # ------------------------------------------------------------------
    # resumable training state (engine.save_train_state envelope body)
    # ------------------------------------------------------------------
    def save_state(self) -> dict:
        """Everything a deterministic resume needs: params, the trainer's jax
        RNG key, and the env's profile state. Subclasses with extra server
        state (FedYogi's optimizer, DTFL's aux heads / scheduler) extend."""
        state = {"params": self.params, "key": np.asarray(self.key),
                 "env": self.env.save_state()}
        if self.codec.stateful:
            state["ef"] = {str(cid): t for cid, t in self._ef.items()}
        return state

    def load_state(self, state: dict) -> None:
        self.params = state["params"]
        if "key" in state:
            self.key = jnp.asarray(state["key"])
        if "env" in state:
            self.env.load_state(state["env"])
        if "ef" in state:
            self._ef = {int(cid): t for cid, t in state["ef"].items()}

    def save(self, path: str) -> None:
        from repro import checkpoint as ckpt

        ckpt.save(path, self.save_state())

    def restore(self, path: str) -> None:
        """Load trainer state from ``path`` — either a bare ``save()`` state
        or a ``fed.engine.save_train_state`` resume envelope (unwrapped)."""
        event_engine.restore_trainer(self, path)

    def run(self, n_rounds: int, eval_batch: dict, *, target_acc: float | None = None,
            participation: float = 1.0, sample_size: int | None = None,
            eval_every: int = 1, verbose: bool = False,
            engine: str = "rounds", churn=None, n_groups: int = 3,
            checkpoint_path: str | None = None, checkpoint_every: int = 10,
            resume: dict | None = None,
            ) -> list[RoundLog]:
        common = dict(
            target_acc=target_acc, participation=participation,
            eval_every=eval_every, verbose=verbose,
            checkpoint_path=checkpoint_path, checkpoint_every=checkpoint_every,
            resume=resume,
        )
        if engine == "events":
            return event_engine.run_events(
                self, n_rounds, eval_batch, churn=churn,
                sample_size=sample_size, **common)
        if engine == "async":
            if not self.supports_async:
                raise ValueError(
                    f"{self.name} has no faithful async formulation (its "
                    "algorithm lives outside train_group); run it with "
                    "engine='rounds' or 'events', or use method 'fedat'"
                )
            if sample_size is not None:
                raise ValueError("sample_size is a rounds/events knob; the "
                                 "async engine groups the full population")
            return event_engine.run_async(
                self, n_rounds, eval_batch, churn=churn, n_groups=n_groups,
                **common)
        if engine != "rounds":
            raise ValueError(f"unknown engine {engine!r}")
        return event_engine.run_rounds(
            self, n_rounds, eval_batch, sample_size=sample_size, **common)

    # ------------------------------------------------------------------
    # time helpers (analytic, from the shared cost table)
    # ------------------------------------------------------------------
    def _full_model_time(self, cid: int, n_batches: int) -> float:
        """FedAvg-style: the client trains the ENTIRE model locally. The
        comm term prices the codec-true download + update upload (identity:
        the legacy ``2 * full_param_bytes``)."""
        prof = self.env.profile(cid)
        compute = self.costs.full_flops * n_batches * self.local_epochs / prof.flops
        comm = (self.wires.full_down + self.wires.full_up) / prof.bytes_per_s
        return compute + comm

    def _local_full_steps(self, r: int, cid: int, params):
        """Run local_epochs of full-model SGD for one client; returns the
        client's (codec'd) upload. The codec's download wire round-trips the
        global before training; the upload wire round-trips the delta."""
        if not hasattr(self, "_full_step"):
            ad, opt = self.adapter, self.opt

            @jax.jit
            def step(p, o, batch):
                loss, g = jax.value_and_grad(lambda q: ad.full_loss(q, batch))(p)
                p, o = opt.update(p, g, o)
                return p, o, loss

            self._full_step = step
        ref = self.codec.tree_down_rt(params)             # download wire
        params = ref
        o = self.opt.init(params)
        for e in range(self.local_epochs):
            for batch in self.clients[cid].dataset.epoch(
                r * pipeline.ROUND_SEED_STRIDE + e
            ):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, o, _ = self._full_step(params, o, batch)
        ef = self._client_ef(cid) if self.codec.stateful else None
        up, ef2 = codec_lib.uplink_rt_one(self.codec, params, ref, ef)
        if self.codec.stateful:
            self._ef[cid] = jax.tree.map(np.asarray, ef2)
        return up

    # ------------------------------------------------------------------
    # cohort / sharded engine paths (same math as _local_full_steps)
    # ------------------------------------------------------------------
    def _full_step_fn(self):
        """Single-client full-model step (unjitted; lifted by run_cohort)."""
        ad, opt = self.adapter, self.opt

        def step(state, batch):
            loss, g = jax.value_and_grad(
                lambda q: ad.full_loss(q, batch)
            )(state["p"])
            p, o = opt.update(state["p"], g, state["o"])
            return {"p": p, "o": o}, loss

        return step

    def _train_round_full(self, r: int, cids: list[int]):
        """Full-model local training for every client in ``cids`` followed by
        the N_k/N weighted average; returns the aggregated params.

        ExecPlan dispatch: ``cohort`` runs vectorized shape-bucketed cohorts
        — one jitted program each (optimizer init + vmap+scan fused on
        device) and a stacked aggregation; ``sharded`` splits each cohort's
        client axis over the plan's mesh and reduces the weighted sums
        on-device (psum); ``loop`` is the per-client debug path.
        """
        weigh = lambda k: len(self.clients[k].dataset)
        if self.exec_plan.mode == "loop":
            locals_ = [self._local_full_steps(r, k, self.params) for k in cids]
            return aggregation.weighted_average(locals_, [weigh(k) for k in cids])
        tier_of = {k: 0 for k in cids}  # untired: bucket by batch shape only
        cohorts = cohort_engine.build_cohorts(
            self.clients, cids, tier_of, r, self.local_epochs,
            pad_multiple=self.exec_plan.pad_multiple,
        )
        if self.exec_plan.mode == "sharded":
            sums, totals = [], []
            for co in cohorts:
                if self.codec.stateful:
                    ef = self._gather_ef(co)
                    s, t, ef2 = self._full_sharded_program()(
                        self.params, co.batches, co.mask,
                        co.client_weights(self.clients), ef,
                    )
                    self._scatter_ef(co, ef2)
                else:
                    s, t = self._full_sharded_program()(
                        self.params, co.batches, co.mask,
                        co.client_weights(self.clients),
                    )
                sums.append(s)
                totals.append(t)
            return aggregation.combine_weighted_sums(sums, totals, like=self.params)
        if not hasattr(self, "_full_cohort_program"):
            step, opt, codec = self._full_step_fn(), self.opt, self.codec

            def body(params, batches, mask):
                ref = codec.tree_down_rt(params)          # download wire
                state = {"p": ref, "o": opt.init(ref)}
                final, _ = cohort_engine.run_cohort(step, state, batches, mask)
                return ref, final["p"]

            if codec.stateful:
                @jax.jit
                def run(params, batches, mask, ef):
                    ref, trained = body(params, batches, mask)
                    up, ef2 = codec_lib.uplink_rt_ef(codec, trained, ref, ef)
                    return up, ef2
            else:
                @jax.jit
                def run(params, batches, mask):
                    ref, trained = body(params, batches, mask)
                    return codec_lib.uplink_rt(codec, trained, ref)

            self._full_cohort_program = run
        if self.exec_plan.mode == "chunked":
            # the SAME compiled cohort program, invoked at chunk width with
            # per-chunk outputs reassembled on host: the device training
            # working set is O(chunk_size), the aggregation below is the
            # identical ``weighted_average_cohorts`` call — bit-equal to the
            # cohort plane by construction (see ExecPlan)
            cs = self.exec_plan.chunk_size
            trees, ws = [], []
            for co in cohorts:
                chunks = []
                for sl in cohort_engine.chunk_slices(co.mask.shape[1], cs):
                    b, m = cohort_engine.slice_clients(co.batches, co.mask, sl)
                    if self.codec.stateful:
                        cids_c = co.cids[sl.start:min(sl.stop, co.size)]
                        ef = self._gather_ef_cids(cids_c, pad_to=cs)
                        up, ef2 = self._full_cohort_program(self.params, b, m, ef)
                        self._scatter_ef_cids(cids_c, ef2)
                    else:
                        up = self._full_cohort_program(self.params, b, m)
                    chunks.append(jax.tree.map(np.asarray, up))
                trees.append(jax.tree.map(
                    lambda *xs: np.concatenate(xs)[:co.size], *chunks))
                ws.append([weigh(k) for k in co.cids])
            return aggregation.weighted_average_cohorts(trees, ws)
        trees, ws = [], []
        for co in cohorts:
            if self.codec.stateful:
                ef = self._gather_ef(co)
                up, ef2 = self._full_cohort_program(
                    self.params, co.batches, co.mask, ef)
                self._scatter_ef(co, ef2)
                trees.append(up)
            else:
                trees.append(
                    self._full_cohort_program(self.params, co.batches, co.mask))
            ws.append([weigh(k) for k in co.cids])
        return aggregation.weighted_average_cohorts(trees, ws)

    def _full_sharded_program(self):
        """One jitted shard_map program: the full-model cohort scan with its
        client axis split over the plan's mesh; the N_k-weighted parameter
        sum and the weight total leave the device pre-reduced (psum), so
        per-client trees never materialize on host. Codec wires apply as in
        the cohort program; error-feedback residuals travel client-sharded."""
        if not hasattr(self, "_full_sharded"):
            step, opt, plan = self._full_step_fn(), self.opt, self.exec_plan
            codec = self.codec

            def train_shard(params, batches, mask):
                ref = codec.tree_down_rt(params)          # download wire
                state = {"p": ref, "o": opt.init(ref)}
                final, _ = cohort_engine.run_cohort(step, state, batches, mask)
                return ref, final["p"]

            if codec.stateful:
                def local(params, batches, mask, weights, ef):
                    ref, trained = train_shard(params, batches, mask)
                    up, ef2 = codec_lib.uplink_rt_ef(codec, trained, ref, ef)
                    return (plan.psum_tree(up, scaled_by=weights),
                            plan.psum_scalar(weights.sum()), ef2)

                self._full_sharded = jax.jit(plan.shard_cohort_call(
                    local, n_replicated=1, n_client_extra=1,
                    n_outs=3, client_outs=1,
                ))
            else:
                def local(params, batches, mask, weights):
                    ref, trained = train_shard(params, batches, mask)
                    up = codec_lib.uplink_rt(codec, trained, ref)
                    return (plan.psum_tree(up, scaled_by=weights),
                            plan.psum_scalar(weights.sum()))

                self._full_sharded = jax.jit(
                    plan.shard_cohort_call(local, n_replicated=1))
        return self._full_sharded
