"""Shared machinery for the baseline federated trainers (paper Sec. 4.1).

All baselines consume the same adapter / clients / env / synthetic clock as
DTFLTrainer so Table-3 style comparisons are apples-to-apples: identical
model, partitions, eval batch; only the algorithm and its time profile vary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, timemodel
from repro.data import pipeline
from repro.fed import cohort as cohort_engine
from repro.fed import engine as event_engine
from repro.fed.client import HeteroEnv, SimClient
from repro.fed.engine import RoundLog, RoundPlan


def kd_loss(student_logits: jax.Array, teacher_logits: jax.Array, temp: float = 1.0) -> jax.Array:
    """KL(teacher || student) with temperature."""
    t = jax.nn.softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    ls = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temp, -1)
    lt = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    return jnp.mean(jnp.sum(t * (lt - ls), -1)) * temp * temp


class BaseTrainer:
    """Round loop scaffolding; subclasses implement train_round()."""

    name = "base"
    # whether the async engine's default train_group (plain FedAvg-style
    # group aggregation) faithfully represents this algorithm. Trainers
    # whose algorithm lives in execute_round / select_clients (fedyogi's
    # server optimizer, fedgkt's KD phases, tifl/drop30's selection) must
    # NOT silently degrade to FedAvg under engine="async".
    supports_async = True

    def __init__(self, adapter, clients: list[SimClient], env: HeteroEnv, optimizer,
                 *, seed: int = 0, local_epochs: int = 1,
                 server_flops: float = timemodel.SERVER_FLOPS, cohort: bool = True):
        self.adapter = adapter
        self.clients = clients
        self.env = env
        self.opt = optimizer
        self.local_epochs = local_epochs
        self.server_flops = server_flops
        self.cohort = cohort
        self.key = jax.random.PRNGKey(seed)
        self.params = adapter.init_global(self._next_key())
        self.costs = adapter.tier_costs(clients[0].dataset.batch_size)

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------
    # engine hooks (fed/engine.py contract). Full-model baselines override
    # select_clients / client_time / execute_round / observe_round; the
    # defaults implement FedAvg semantics.
    # ------------------------------------------------------------------
    def select_clients(self, r: int, participants: list[int]) -> list[int]:
        """Which participants actually train (TiFL picks a tier, drop30 the
        fastest subset)."""
        return list(participants)

    def client_time(self, k: int) -> float:
        """Planned Eq.-5 completion offset for client ``k`` under this
        algorithm's time profile."""
        return self._full_model_time(k, self.clients[k].n_batches)

    def plan_round(self, r: int, participants: list[int]) -> RoundPlan:
        self.env.maybe_switch(r)
        trained = list(self.select_clients(r, participants))
        times = np.array([self.client_time(k) for k in trained], float)
        return RoundPlan(
            participants=list(participants), trained=trained,
            assign={k: 0 for k in trained}, times=times,
        )

    def execute_round(self, r: int, plan: RoundPlan, trained: list[int]) -> float:
        """Train the survivors; returns extra serial time (FedGKT's server
        phase) appended after the last completion."""
        if trained:
            self.params = self._train_round_full(r, trained)
        return 0.0

    def observe_round(self, plan: RoundPlan, idx: list[int], obs_times, totals) -> None:
        """Feed event-derived timestamps back (TiFL's speed profiling)."""

    def train_group(self, r: int, plan: RoundPlan, trained: list[int]):
        """Async-tier hook: group aggregate without committing to params."""
        tree = self._train_round_full(r, trained)
        return tree, float(sum(len(self.clients[k].dataset) for k in trained))

    def async_groups(self, cids: list[int], n_groups: int) -> list[list[int]]:
        """Speed groups (fast -> slow) by this algorithm's own time profile —
        the FedAT/TiFL tier-profiling step."""
        return event_engine.split_speed_groups(
            sorted(cids, key=self.client_time), n_groups
        )

    # ------------------------------------------------------------------
    def train_round(self, r: int, participants: list[int]) -> float:
        """Legacy scalar-clock round: plan -> execute(all) -> observe(all)."""
        plan = self.plan_round(r, participants)
        extra = self.execute_round(r, plan, plan.trained)
        self.observe_round(
            plan, list(range(len(plan.trained))), plan.times, plan.times
        )
        return float(plan.times.max()) + extra

    def run(self, n_rounds: int, eval_batch: dict, *, target_acc: float | None = None,
            participation: float = 1.0, eval_every: int = 1, verbose: bool = False,
            engine: str = "rounds", churn=None, n_groups: int = 3,
            ) -> list[RoundLog]:
        if engine == "events":
            return event_engine.run_events(
                self, n_rounds, eval_batch, target_acc=target_acc,
                participation=participation, eval_every=eval_every,
                verbose=verbose, churn=churn,
            )
        if engine == "async":
            if not self.supports_async:
                raise ValueError(
                    f"{self.name} has no faithful async formulation (its "
                    "algorithm lives outside train_group); run it with "
                    "engine='rounds' or 'events', or use method 'fedat'"
                )
            return event_engine.run_async(
                self, n_rounds, eval_batch, target_acc=target_acc,
                participation=participation, eval_every=eval_every,
                verbose=verbose, churn=churn, n_groups=n_groups,
            )
        if engine != "rounds":
            raise ValueError(f"unknown engine {engine!r}")
        rng = np.random.default_rng(0)
        eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        eval_fn = jax.jit(self.adapter.eval_acc)
        clock, logs = 0.0, []
        n_part = max(1, int(participation * len(self.clients)))
        for r in range(n_rounds):
            participants = sorted(rng.choice(len(self.clients), n_part, replace=False).tolist())
            straggler = self.train_round(r, participants)
            clock += straggler
            acc = float(eval_fn(self.params, eval_batch)) if r % eval_every == 0 else (
                logs[-1].acc if logs else 0.0)
            logs.append(RoundLog(r, clock, acc, {}, straggler))
            if verbose:
                print(f"[{self.name}] r={r} clock={clock:.0f}s acc={acc:.3f}")
            if target_acc is not None and acc >= target_acc:
                break
        return logs

    # ------------------------------------------------------------------
    # time helpers (analytic, from the shared cost table)
    # ------------------------------------------------------------------
    def _full_model_time(self, cid: int, n_batches: int) -> float:
        """FedAvg-style: the client trains the ENTIRE model locally."""
        prof = self.env.profile(cid)
        compute = self.costs.full_flops * n_batches * self.local_epochs / prof.flops
        comm = 2.0 * self.costs.full_param_bytes / prof.bytes_per_s
        return compute + comm

    def _local_full_steps(self, r: int, cid: int, params):
        """Run local_epochs of full-model SGD for one client; returns params."""
        if not hasattr(self, "_full_step"):
            ad, opt = self.adapter, self.opt

            @jax.jit
            def step(p, o, batch):
                loss, g = jax.value_and_grad(lambda q: ad.full_loss(q, batch))(p)
                p, o = opt.update(p, g, o)
                return p, o, loss

            self._full_step = step
        o = self.opt.init(params)
        for e in range(self.local_epochs):
            for batch in self.clients[cid].dataset.epoch(
                r * pipeline.ROUND_SEED_STRIDE + e
            ):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, o, _ = self._full_step(params, o, batch)
        return params

    # ------------------------------------------------------------------
    # cohort engine path (same math as _local_full_steps, vectorized)
    # ------------------------------------------------------------------
    def _train_round_full(self, r: int, cids: list[int]):
        """Full-model local training for every client in ``cids`` followed by
        the N_k/N weighted average; returns the aggregated params.

        With ``cohort=True`` the clients run as vectorized shape-bucketed
        cohorts — one jitted program each (optimizer init + vmap+scan fused
        on device) and a stacked aggregation; otherwise the per-client loop.
        """
        weigh = lambda k: len(self.clients[k].dataset)
        if not self.cohort:
            locals_ = [self._local_full_steps(r, k, self.params) for k in cids]
            return aggregation.weighted_average(locals_, [weigh(k) for k in cids])
        if not hasattr(self, "_full_cohort_program"):
            ad, opt = self.adapter, self.opt

            def step(state, batch):
                loss, g = jax.value_and_grad(
                    lambda q: ad.full_loss(q, batch)
                )(state["p"])
                p, o = opt.update(state["p"], g, state["o"])
                return {"p": p, "o": o}, loss

            @jax.jit
            def run(params, batches, mask):
                state = {"p": params, "o": opt.init(params)}
                final, _ = cohort_engine.run_cohort(step, state, batches, mask)
                return final["p"]

            self._full_cohort_program = run
        trees, ws = [], []
        tier_of = {k: 0 for k in cids}  # untired: bucket by batch shape only
        for co in cohort_engine.build_cohorts(
            self.clients, cids, tier_of, r, self.local_epochs
        ):
            trees.append(self._full_cohort_program(self.params, co.batches, co.mask))
            ws.append([weigh(k) for k in co.cids])
        return aggregation.weighted_average_cohorts(trees, ws)
