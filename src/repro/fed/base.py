"""Shared machinery for the baseline federated trainers (paper Sec. 4.1).

All baselines consume the same adapter / clients / env / synthetic clock as
DTFLTrainer so Table-3 style comparisons are apples-to-apples: identical
model, partitions, eval batch; only the algorithm and its time profile vary.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, timemodel
from repro.fed.client import HeteroEnv, SimClient
from repro.fed.dtfl import RoundLog


def kd_loss(student_logits: jax.Array, teacher_logits: jax.Array, temp: float = 1.0) -> jax.Array:
    """KL(teacher || student) with temperature."""
    t = jax.nn.softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    ls = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temp, -1)
    lt = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    return jnp.mean(jnp.sum(t * (lt - ls), -1)) * temp * temp


class BaseTrainer:
    """Round loop scaffolding; subclasses implement train_round()."""

    name = "base"

    def __init__(self, adapter, clients: list[SimClient], env: HeteroEnv, optimizer,
                 *, seed: int = 0, local_epochs: int = 1,
                 server_flops: float = timemodel.SERVER_FLOPS):
        self.adapter = adapter
        self.clients = clients
        self.env = env
        self.opt = optimizer
        self.local_epochs = local_epochs
        self.server_flops = server_flops
        self.key = jax.random.PRNGKey(seed)
        self.params = adapter.init_global(self._next_key())
        self.costs = adapter.tier_costs(clients[0].dataset.batch_size)

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------
    def train_round(self, r: int, participants: list[int]) -> float:
        raise NotImplementedError

    def run(self, n_rounds: int, eval_batch: dict, *, target_acc: float | None = None,
            participation: float = 1.0, eval_every: int = 1, verbose: bool = False
            ) -> list[RoundLog]:
        rng = np.random.default_rng(0)
        eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        eval_fn = jax.jit(self.adapter.eval_acc)
        clock, logs = 0.0, []
        n_part = max(1, int(participation * len(self.clients)))
        for r in range(n_rounds):
            participants = sorted(rng.choice(len(self.clients), n_part, replace=False).tolist())
            self.env.maybe_switch(r)
            straggler = self.train_round(r, participants)
            clock += straggler
            acc = float(eval_fn(self.params, eval_batch)) if r % eval_every == 0 else (
                logs[-1].acc if logs else 0.0)
            logs.append(RoundLog(r, clock, acc, {}, straggler))
            if verbose:
                print(f"[{self.name}] r={r} clock={clock:.0f}s acc={acc:.3f}")
            if target_acc is not None and acc >= target_acc:
                break
        return logs

    # ------------------------------------------------------------------
    # time helpers (analytic, from the shared cost table)
    # ------------------------------------------------------------------
    def _full_model_time(self, cid: int, n_batches: int) -> float:
        """FedAvg-style: the client trains the ENTIRE model locally."""
        prof = self.env.profile(cid)
        compute = self.costs.full_flops * n_batches * self.local_epochs / prof.flops
        comm = 2.0 * self.costs.full_param_bytes / prof.bytes_per_s
        return compute + comm

    def _local_full_steps(self, r: int, cid: int, params):
        """Run local_epochs of full-model SGD for one client; returns params."""
        if not hasattr(self, "_full_step"):
            ad, opt = self.adapter, self.opt

            @jax.jit
            def step(p, o, batch):
                loss, g = jax.value_and_grad(lambda q: ad.full_loss(q, batch))(p)
                p, o = opt.update(p, g, o)
                return p, o, loss

            self._full_step = step
        o = self.opt.init(params)
        for e in range(self.local_epochs):
            for batch in self.clients[cid].dataset.epoch(r * 131 + e):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, o, _ = self._full_step(params, o, batch)
        return params
