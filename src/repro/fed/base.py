"""Shared machinery for the baseline federated trainers (paper Sec. 4.1).

All baselines consume the same adapter / clients / env / synthetic clock as
DTFLTrainer so Table-3 style comparisons are apples-to-apples: identical
model, partitions, eval batch; only the algorithm and its time profile vary.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, timemodel
from repro.data import pipeline
from repro.fed import cohort as cohort_engine
from repro.fed.client import HeteroEnv, SimClient
from repro.fed.dtfl import RoundLog


def kd_loss(student_logits: jax.Array, teacher_logits: jax.Array, temp: float = 1.0) -> jax.Array:
    """KL(teacher || student) with temperature."""
    t = jax.nn.softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    ls = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temp, -1)
    lt = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / temp, -1)
    return jnp.mean(jnp.sum(t * (lt - ls), -1)) * temp * temp


class BaseTrainer:
    """Round loop scaffolding; subclasses implement train_round()."""

    name = "base"

    def __init__(self, adapter, clients: list[SimClient], env: HeteroEnv, optimizer,
                 *, seed: int = 0, local_epochs: int = 1,
                 server_flops: float = timemodel.SERVER_FLOPS, cohort: bool = True):
        self.adapter = adapter
        self.clients = clients
        self.env = env
        self.opt = optimizer
        self.local_epochs = local_epochs
        self.server_flops = server_flops
        self.cohort = cohort
        self.key = jax.random.PRNGKey(seed)
        self.params = adapter.init_global(self._next_key())
        self.costs = adapter.tier_costs(clients[0].dataset.batch_size)

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------
    def train_round(self, r: int, participants: list[int]) -> float:
        raise NotImplementedError

    def run(self, n_rounds: int, eval_batch: dict, *, target_acc: float | None = None,
            participation: float = 1.0, eval_every: int = 1, verbose: bool = False
            ) -> list[RoundLog]:
        rng = np.random.default_rng(0)
        eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        eval_fn = jax.jit(self.adapter.eval_acc)
        clock, logs = 0.0, []
        n_part = max(1, int(participation * len(self.clients)))
        for r in range(n_rounds):
            participants = sorted(rng.choice(len(self.clients), n_part, replace=False).tolist())
            self.env.maybe_switch(r)
            straggler = self.train_round(r, participants)
            clock += straggler
            acc = float(eval_fn(self.params, eval_batch)) if r % eval_every == 0 else (
                logs[-1].acc if logs else 0.0)
            logs.append(RoundLog(r, clock, acc, {}, straggler))
            if verbose:
                print(f"[{self.name}] r={r} clock={clock:.0f}s acc={acc:.3f}")
            if target_acc is not None and acc >= target_acc:
                break
        return logs

    # ------------------------------------------------------------------
    # time helpers (analytic, from the shared cost table)
    # ------------------------------------------------------------------
    def _full_model_time(self, cid: int, n_batches: int) -> float:
        """FedAvg-style: the client trains the ENTIRE model locally."""
        prof = self.env.profile(cid)
        compute = self.costs.full_flops * n_batches * self.local_epochs / prof.flops
        comm = 2.0 * self.costs.full_param_bytes / prof.bytes_per_s
        return compute + comm

    def _local_full_steps(self, r: int, cid: int, params):
        """Run local_epochs of full-model SGD for one client; returns params."""
        if not hasattr(self, "_full_step"):
            ad, opt = self.adapter, self.opt

            @jax.jit
            def step(p, o, batch):
                loss, g = jax.value_and_grad(lambda q: ad.full_loss(q, batch))(p)
                p, o = opt.update(p, g, o)
                return p, o, loss

            self._full_step = step
        o = self.opt.init(params)
        for e in range(self.local_epochs):
            for batch in self.clients[cid].dataset.epoch(
                r * pipeline.ROUND_SEED_STRIDE + e
            ):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, o, _ = self._full_step(params, o, batch)
        return params

    # ------------------------------------------------------------------
    # cohort engine path (same math as _local_full_steps, vectorized)
    # ------------------------------------------------------------------
    def _train_round_full(self, r: int, cids: list[int]):
        """Full-model local training for every client in ``cids`` followed by
        the N_k/N weighted average; returns the aggregated params.

        With ``cohort=True`` the clients run as vectorized shape-bucketed
        cohorts — one jitted program each (optimizer init + vmap+scan fused
        on device) and a stacked aggregation; otherwise the per-client loop.
        """
        weigh = lambda k: len(self.clients[k].dataset)
        if not self.cohort:
            locals_ = [self._local_full_steps(r, k, self.params) for k in cids]
            return aggregation.weighted_average(locals_, [weigh(k) for k in cids])
        if not hasattr(self, "_full_cohort_program"):
            ad, opt = self.adapter, self.opt

            def step(state, batch):
                loss, g = jax.value_and_grad(
                    lambda q: ad.full_loss(q, batch)
                )(state["p"])
                p, o = opt.update(state["p"], g, state["o"])
                return {"p": p, "o": o}, loss

            @jax.jit
            def run(params, batches, mask):
                state = {"p": params, "o": opt.init(params)}
                final, _ = cohort_engine.run_cohort(step, state, batches, mask)
                return final["p"]

            self._full_cohort_program = run
        trees, ws = [], []
        tier_of = {k: 0 for k in cids}  # untired: bucket by batch shape only
        for co in cohort_engine.build_cohorts(
            self.clients, cids, tier_of, r, self.local_epochs
        ):
            trees.append(self._full_cohort_program(self.params, co.batches, co.mask))
            ws.append([weigh(k) for k in co.cids])
        return aggregation.weighted_average_cohorts(trees, ws)
