"""TiFL-style tier-based client SELECTION (Chai et al. 2020) — the tier-based
line of work the paper builds on: clients are profiled into speed tiers and
each round trains clients FROM ONE TIER (rotating by an accuracy credit),
but every client still trains the FULL model. Included as the reference
point between FedAvg and DTFL: selection removes intra-round stragglers but
pays full-model time on slow tiers and skips data every round.

Speed profiling consumes the event-derived completion timestamps
(``observe_round``): under churn, only clients that actually reported
refresh their profile, exactly like a real TiFL server.
"""
from __future__ import annotations

import numpy as np

from repro.fed.base import BaseTrainer, RoundPlan

N_TIERS = 3


class TiFLTrainer(BaseTrainer):
    name = "tifl"
    supports_async = False  # algorithm lives outside train_group

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._speed_obs = {}          # cid -> last observed full-model time
        self._round_robin = 0

    def _tiers(self, participants):
        # profile clients by observed (or estimated) full-model time
        times = {k: self._speed_obs.get(k, self.client_time(k)) for k in participants}
        order = sorted(participants, key=lambda k: times[k])
        cut = max(1, len(order) // N_TIERS)
        return [order[i * cut : (i + 1) * cut] or order[-1:] for i in range(N_TIERS)]

    def select_clients(self, r: int, participants: list[int]) -> list[int]:
        tiers = self._tiers(participants)
        chosen = tiers[self._round_robin % len(tiers)]
        self._round_robin += 1
        return chosen

    def observe_round(self, plan: RoundPlan, idx: list[int], obs_times, totals) -> None:
        for j, i in enumerate(idx):
            self._speed_obs[plan.trained[i]] = float(totals[j])

    # speed profile + tier rotation ride the resume envelope, otherwise a
    # resumed run re-profiles from scratch and selects different tiers
    def save_state(self) -> dict:
        state = super().save_state()
        cids = np.array(sorted(self._speed_obs), dtype=np.int64)
        state["tifl"] = {
            "obs_cids": cids,
            "obs_times": np.array([self._speed_obs[int(c)] for c in cids]),
            "round_robin": np.int64(self._round_robin),
        }
        return state

    def load_state(self, state: dict) -> None:
        super().load_state(state)
        if "tifl" in state:
            t = state["tifl"]
            self._speed_obs = {
                int(c): float(v)
                for c, v in zip(np.asarray(t["obs_cids"]).reshape(-1),
                                np.asarray(t["obs_times"]).reshape(-1))
            }
            self._round_robin = int(t["round_robin"])
