"""TiFL-style tier-based client SELECTION (Chai et al. 2020) — the tier-based
line of work the paper builds on: clients are profiled into speed tiers and
each round trains clients FROM ONE TIER (rotating by an accuracy credit),
but every client still trains the FULL model. Included as the reference
point between FedAvg and DTFL: selection removes intra-round stragglers but
pays full-model time on slow tiers and skips data every round.
"""
from __future__ import annotations

import numpy as np

from repro.fed.base import BaseTrainer

N_TIERS = 3


class TiFLTrainer(BaseTrainer):
    name = "tifl"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._speed_obs = {}          # cid -> last full-model time
        self._round_robin = 0

    def _tiers(self, participants):
        # profile clients by observed (or estimated) full-model time
        times = {
            k: self._speed_obs.get(k, self._full_model_time(k, self.clients[k].n_batches))
            for k in participants
        }
        order = sorted(participants, key=lambda k: times[k])
        cut = max(1, len(order) // N_TIERS)
        return [order[i * cut : (i + 1) * cut] or order[-1:] for i in range(N_TIERS)]

    def train_round(self, r: int, participants: list[int]) -> float:
        tiers = self._tiers(participants)
        chosen = tiers[self._round_robin % len(tiers)]
        self._round_robin += 1
        self.params = self._train_round_full(r, chosen)
        times = []
        for k in chosen:
            t = self._full_model_time(k, self.clients[k].n_batches)
            self._speed_obs[k] = t
            times.append(t)
        return max(times)
