"""Simulated heterogeneous client population (paper Sec. 4.1).

Each client owns a data partition and a resource profile; the environment
re-assigns profiles for a fraction of clients every ``switch_every`` rounds
("Every 50 rounds, the client profiles of 30% of the clients were randomly
changed"). Ground-truth profiles are visible only to the time simulator,
never to the scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.timemodel import PAPER_PROFILES, ResourceProfile
from repro.data.pipeline import ClientDataset


@dataclass
class SimClient:
    cid: int
    dataset: ClientDataset
    profile: ResourceProfile

    @property
    def n_batches(self) -> int:
        return self.dataset.n_batches


class HeteroEnv:
    """Profile assignment + dynamics."""

    def __init__(
        self,
        n_clients: int,
        profiles: list[ResourceProfile] | None = None,
        *,
        switch_every: int = 50,
        switch_frac: float = 0.3,
        seed: int = 0,
    ):
        self.profiles = profiles or PAPER_PROFILES
        self.switch_every = switch_every
        self.switch_frac = switch_frac
        self.rng = np.random.default_rng(seed)
        # paper: 20% of clients per profile at the outset (even split)
        idx = np.resize(np.arange(len(self.profiles)), n_clients)
        self.rng.shuffle(idx)
        self.assignment = idx
        self._switched_rounds: set[int] = set()

    def maybe_switch(self, round_idx: int) -> None:
        # each round index switches at most once: the async engine plans every
        # GROUP's wave through plan_round, so without this guard a multiple of
        # switch_every would re-roll profiles once per group
        if (self.switch_every and round_idx > 0 and round_idx % self.switch_every == 0
                and round_idx not in self._switched_rounds):
            self._switched_rounds.add(round_idx)
            n = len(self.assignment)
            sel = self.rng.choice(n, size=max(1, int(self.switch_frac * n)), replace=False)
            self.assignment[sel] = self.rng.integers(0, len(self.profiles), len(sel))

    def set_profile(self, cid: int, profile_idx: int) -> None:
        """Point mutation used by mid-round churn events (fed/engine.py)."""
        self.assignment[cid] = profile_idx

    def profile(self, cid: int) -> ResourceProfile:
        return self.profiles[self.assignment[cid]]

    # ------------------------------------------------------------------
    # resumable-training state (profile assignment + the switch rng stream)
    # ------------------------------------------------------------------
    def save_state(self) -> dict:
        from repro import checkpoint as ckpt

        switched = np.array(sorted(self._switched_rounds), dtype=np.int64)
        return {"assignment": self.assignment.copy(),
                "rng": ckpt.pack_rng(self.rng),
                "switched": switched}

    def load_state(self, state: dict) -> None:
        from repro import checkpoint as ckpt

        self.assignment = np.asarray(state["assignment"]).copy()
        self.rng = ckpt.unpack_rng(state["rng"])
        self._switched_rounds = {int(r) for r in np.asarray(state["switched"]).reshape(-1)}


class ChurnModel:
    """Client churn for the event engine: dropout, arrival, mid-round switches.

    Three dynamics, all sampled from the model's own rng (so enabling churn
    never perturbs participant sampling or training seeds):

    * **dropout** — with ``drop_prob`` per participant per round, the client
      goes offline at a uniform fraction of its planned completion time; its
      completion event is cancelled, it is excluded from aggregation and from
      scheduler observations, and it returns after ``rejoin_after`` rounds.
    * **arrival** — a ``start_offline_frac`` fraction of the roster begins
      outside the federation; each offline-from-start client joins with
      ``arrival_prob`` per round (new devices appearing mid-training).
    * **mid-round profile switch** — with ``switch_prob`` per participant per
      round, the client's ground-truth resource profile is re-rolled *while
      its round is in flight*; the engine reschedules its completion event
      via :func:`repro.core.timemodel.rescale_remaining`, and the scheduler
      observes the event-derived time, not the planned one.

    The scheduler only ever sees event timestamps of clients that actually
    reported — dropped clients leave no observation, so its estimate matrix
    stays finite (tested in ``tests/test_events.py``).
    """

    def __init__(self, n_clients: int, *, drop_prob: float = 0.0,
                 rejoin_after: int = 2, switch_prob: float = 0.0,
                 start_offline_frac: float = 0.0, arrival_prob: float = 0.5,
                 seed: int = 0):
        self.n = n_clients
        self.drop_prob = drop_prob
        self.rejoin_after = max(1, int(rejoin_after))
        self.switch_prob = switch_prob
        self.arrival_prob = arrival_prob
        self.rng = np.random.default_rng(seed)
        # cid -> rounds until eligible again; None = offline-from-start,
        # waiting for an arrival draw
        self.offline: dict[int, int | None] = {}
        if start_offline_frac > 0.0:
            k = min(n_clients - 1, int(round(start_offline_frac * n_clients)))
            for cid in self.rng.choice(n_clients, size=k, replace=False):
                self.offline[int(cid)] = None

    # ------------------------------------------------------------------
    def begin_round(self, r: int) -> np.ndarray:
        """Advance offline countdowns / arrival draws; return active cids."""
        back = []
        for cid, left in list(self.offline.items()):
            if left is None:
                if self.rng.random() < self.arrival_prob:
                    back.append(cid)
            elif left <= 1:
                back.append(cid)
            else:
                self.offline[cid] = left - 1
        for cid in back:
            del self.offline[cid]
        active = np.array(
            [c for c in range(self.n) if c not in self.offline], dtype=int
        )
        if not len(active):
            # the federation never fully empties: if everyone is offline the
            # whole roster rejoins (and the bookkeeping agrees with active())
            self.offline.clear()
            return np.arange(self.n)
        return active

    def active(self) -> list[int]:
        return [c for c in range(self.n) if c not in self.offline]

    def mark_offline(self, cid: int) -> None:
        self.offline[cid] = self.rejoin_after

    # ------------------------------------------------------------------
    def sample_mid_round(self, trained: list[int], times) -> list[tuple]:
        """Per-round churn draws: ``(kind, idx, at_fraction)`` tuples where
        ``kind`` is "dropout" | "switch" and ``at_fraction`` in (0, 1) is the
        fraction of the client's planned completion time at which it fires."""
        out = []
        for i, _ in enumerate(trained):
            u = self.rng.random()
            if u < self.drop_prob:
                out.append(("dropout", i, float(self.rng.uniform(0.05, 0.95))))
            elif u < self.drop_prob + self.switch_prob:
                out.append(("switch", i, float(self.rng.uniform(0.05, 0.95))))
        return out

    def resample_profile(self, env: HeteroEnv, cid: int) -> None:
        env.set_profile(cid, int(self.rng.integers(0, len(env.profiles))))
