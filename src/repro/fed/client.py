"""Simulated heterogeneous client population (paper Sec. 4.1).

Each client owns a data partition and a resource profile; the environment
re-assigns profiles for a fraction of clients every ``switch_every`` rounds
("Every 50 rounds, the client profiles of 30% of the clients were randomly
changed"). Ground-truth profiles are visible only to the time simulator,
never to the scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.timemodel import PAPER_PROFILES, ResourceProfile
from repro.data.pipeline import ClientDataset


@dataclass
class SimClient:
    cid: int
    dataset: ClientDataset
    profile: ResourceProfile

    @property
    def n_batches(self) -> int:
        return self.dataset.n_batches


class HeteroEnv:
    """Profile assignment + dynamics."""

    def __init__(
        self,
        n_clients: int,
        profiles: list[ResourceProfile] | None = None,
        *,
        switch_every: int = 50,
        switch_frac: float = 0.3,
        seed: int = 0,
    ):
        self.profiles = profiles or PAPER_PROFILES
        self.switch_every = switch_every
        self.switch_frac = switch_frac
        self.rng = np.random.default_rng(seed)
        # paper: 20% of clients per profile at the outset (even split)
        idx = np.resize(np.arange(len(self.profiles)), n_clients)
        self.rng.shuffle(idx)
        self.assignment = idx

    def maybe_switch(self, round_idx: int) -> None:
        if self.switch_every and round_idx > 0 and round_idx % self.switch_every == 0:
            n = len(self.assignment)
            sel = self.rng.choice(n, size=max(1, int(self.switch_frac * n)), replace=False)
            self.assignment[sel] = self.rng.integers(0, len(self.profiles), len(sel))

    def profile(self, cid: int) -> ResourceProfile:
        return self.profiles[self.assignment[cid]]
