"""ExecPlan: the explicit execution contract of the federation plane.

PR 1/2 grew three ways to run a round's client computations — the per-client
sequential loop, the vectorized tier-cohort programs, and (this PR) cohort
programs sharded over a JAX device mesh — selected by an ad-hoc
``cohort: bool`` flag on every trainer. ``ExecPlan`` replaces that flag with
one value threaded from ``train.py --exec cohort|loop|sharded --devices N``
through the trainers and both engines down to ``fed/cohort.py``:

* ``mode`` — ``"loop"`` (per-client debug path), ``"cohort"`` (one vmap+scan
  program per tier/shape bucket, single device), ``"sharded"`` (the same
  cohort programs with their client axis split across ``mesh`` via
  ``shard_map``; cross-client weighted sums become on-device ``psum``
  collectives, so per-client parameter trees never travel to the host).
* ``mesh`` / ``axis`` — the 1-D client-axis mesh (``launch.mesh.
  make_sim_mesh``) and the name of its sharded axis.
* ``pad_multiple`` — ragged cohorts pad their client axis up to a multiple
  of the mesh's axis size (padded clients carry zero batches, an all-False
  step mask, and weight 0, so they are exact no-ops).

Helpers here are the only place that knows shard_map/PartitionSpec details;
trainers compose them inside their jitted per-tier programs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

MODES = ("loop", "cohort", "sharded", "chunked")

DEFAULT_CHUNK_SIZE = 16


@dataclass(frozen=True)
class ExecPlan:
    """Execution mode + mesh/shard/pad/chunk policy for one trainer.

    ``mode="chunked"`` runs each (tier, shape) cohort as a sequence of
    fixed-size client CHUNKS through the SAME compiled per-tier cohort
    program at chunk width — the device training working set (stacked
    batches, per-client optimizer states, activations) is O(chunk_size),
    not O(cohort), which is what lets a 512-participant sample from a 100k
    registry train on a small host. Per-chunk outputs reassemble on the
    host and flow through the identical aggregation, so the round is
    bit-for-bit equal to ``cohort`` BY CONSTRUCTION — pinned by
    ``tests/test_population.py``. (Eager per-chunk invocations of the same
    program are bitwise equal to slices of the full-cohort vmap; folding
    across chunks inside one program is NOT — XLA CPU compiles conv
    gradients differently inside a ``lax.scan`` body and re-fuses weighted
    sums across the chunk boundary — so the chunk loop stays on the host.)
    """

    mode: str = "cohort"
    mesh: Any = None          # jax.sharding.Mesh, required for mode="sharded"
    axis: str = "clients"
    chunk_size: int | None = None   # client-chunk length, mode="chunked" only

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown exec mode {self.mode!r}; pick from {MODES}")
        if self.mode == "sharded" and self.mesh is None:
            raise ValueError("ExecPlan(mode='sharded') needs a mesh; use "
                             "ExecPlan.sharded(devices=N) or pass one from "
                             "launch.mesh.make_sim_mesh")
        if self.mode == "chunked":
            if self.chunk_size is None:
                object.__setattr__(self, "chunk_size", DEFAULT_CHUNK_SIZE)
            if self.chunk_size < 1:
                raise ValueError(
                    f"ExecPlan(mode='chunked') needs chunk_size >= 1, got "
                    f"{self.chunk_size!r}")
        elif self.chunk_size is not None:
            raise ValueError(
                f"chunk_size is a mode='chunked' knob; mode={self.mode!r} "
                "does not take one")

    # ------------------------------------------------------------------
    @classmethod
    def loop(cls) -> "ExecPlan":
        return cls(mode="loop")

    @classmethod
    def cohort(cls) -> "ExecPlan":
        return cls(mode="cohort")

    @classmethod
    def chunked(cls, chunk_size: int | None = None) -> "ExecPlan":
        return cls(mode="chunked", chunk_size=chunk_size)

    @classmethod
    def sharded(cls, mesh=None, *, devices: int | None = None) -> "ExecPlan":
        if mesh is None:
            from repro.launch.mesh import make_sim_mesh

            mesh = make_sim_mesh(devices)
        (axis,) = mesh.axis_names
        return cls(mode="sharded", mesh=mesh, axis=axis)

    @classmethod
    def from_flags(cls, exec_mode: str, *, devices: int | None = None,
                   chunk_size: int | None = None) -> "ExecPlan":
        """CLI adapter: ``--exec`` + ``--devices``/``--chunk-size`` -> ExecPlan."""
        if exec_mode == "sharded":
            return cls.sharded(devices=devices)
        if exec_mode == "chunked":
            return cls.chunked(chunk_size)
        return cls(mode=exec_mode)

    @classmethod
    def resolve(cls, plan: "ExecPlan | str | None") -> "ExecPlan":
        """Trainer-ctor adapter: None -> cohort default, str -> mode name."""
        if plan is None:
            return cls.cohort()
        if isinstance(plan, str):
            return cls.from_flags(plan)
        return plan

    # ------------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.axis]

    @property
    def pad_multiple(self) -> int:
        """Client-axis divisibility required by this plan's sharding/chunking."""
        if self.mode == "sharded":
            return self.n_shards
        if self.mode == "chunked":
            return self.chunk_size
        return 1

    def describe(self) -> str:
        if self.mode == "sharded":
            return f"sharded[{self.axis}={self.n_shards}]"
        if self.mode == "chunked":
            return f"chunked[{self.chunk_size}]"
        return self.mode

    # ------------------------------------------------------------------
    # shard_map plumbing (the one place PartitionSpecs live)
    # ------------------------------------------------------------------
    def shard_cohort_call(self, local_fn, n_replicated: int = 0,
                          n_client_extra: int = 0, n_outs: int = 1,
                          client_outs: int = 0):
        """Wrap ``local_fn(*replicated, batches, mask, weights, *client_extra)
        -> out`` so the cohort arguments arrive client-sharded and the
        reduced outputs leave replicated.

        ``local_fn`` sees per-shard slices: batches ``(S, C/n, ...)``, mask
        ``(S, C/n)``, weights ``(C/n,)``; it must reduce its cross-client
        outputs across ``self.axis`` itself (``psum_tree`` / ``lax.psum``)
        so the replicated out_specs hold. The first ``n_replicated``
        arguments (global params, tier aux heads, ...) are broadcast to
        every shard unchanged.

        ``n_client_extra`` trailing arguments carry additional per-client
        state pytrees (leading client axis — the codec plane's
        error-feedback residuals) sharded like the cohort; the LAST
        ``client_outs`` of the ``n_outs`` outputs are per-client pytrees
        that come back sharded (everything before them is psum-reduced and
        replicated).
        """
        rep = (P(),) * n_replicated
        in_specs = (rep
                    + (P(None, self.axis), P(None, self.axis), P(self.axis))
                    + (P(self.axis),) * n_client_extra)
        if client_outs:
            out_specs = tuple([P()] * (n_outs - client_outs)
                              + [P(self.axis)] * client_outs)
        else:
            out_specs = P()
        return shard_map(
            local_fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
        )

    def psum_tree(self, tree, scaled_by=None):
        """On-device cross-shard reduction of a weighted-sum pytree."""
        if scaled_by is not None:
            tree = weighted_sum(tree, scaled_by)
        return jax.tree.map(lambda x: jax.lax.psum(x, self.axis), tree)

    def psum_scalar(self, x):
        return jax.lax.psum(x, self.axis)


def weighted_sum(tree, weights):
    """Contract a pytree's leading client axis against ``weights`` (f32).

    Exactly the per-cohort partial of ``core.aggregation._wavg_cohorts``
    (``tensordot(w, x.astype(f32), axes=1)``), so the sharded plane's
    host-side combine reproduces the cohort plane's math bit-for-bit on a
    1-device mesh.
    """
    w = jnp.asarray(weights, jnp.float32)
    return jax.tree.map(lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=1), tree)
