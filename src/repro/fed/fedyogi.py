"""FedYogi (Reddi et al. 2020): FedAvg client updates + Yogi server optimizer.

The server treats the negative average client delta as a pseudo-gradient and
applies the Yogi adaptive update. Client-side time profile equals FedAvg's
(full model locally), so only execute_round differs from the defaults.
"""
from __future__ import annotations

import jax

from repro.fed.base import BaseTrainer, RoundPlan
from repro import optim


class FedYogiTrainer(BaseTrainer):
    name = "fedyogi"
    supports_async = False  # algorithm lives outside train_group

    def __init__(self, *args, server_lr: float = 0.05, **kw):
        super().__init__(*args, **kw)
        self.server_opt = optim.yogi(lr=server_lr)
        self.server_opt_state = self.server_opt.init(self.params)

    def execute_round(self, r: int, plan: RoundPlan, trained: list[int]) -> float:
        if not trained:
            return 0.0
        avg = self._train_round_full(r, trained)
        pseudo_grad = jax.tree.map(lambda g, l: g - l, self.params, avg)
        self.params, self.server_opt_state = self.server_opt.update(
            self.params, pseudo_grad, self.server_opt_state
        )
        return 0.0

    # persistent server-side optimizer state rides the resume envelope
    def save_state(self) -> dict:
        state = super().save_state()
        state["server_opt"] = self.server_opt_state
        return state

    def load_state(self, state: dict) -> None:
        super().load_state(state)
        if "server_opt" in state:
            self.server_opt_state = state["server_opt"]
