"""FedYogi (Reddi et al. 2020): FedAvg client updates + Yogi server optimizer.

The server treats the negative average client delta as a pseudo-gradient and
applies the Yogi adaptive update. Client-side time profile equals FedAvg's
(full model locally).
"""
from __future__ import annotations

import jax

from repro.fed.base import BaseTrainer
from repro import optim


class FedYogiTrainer(BaseTrainer):
    name = "fedyogi"

    def __init__(self, *args, server_lr: float = 0.05, **kw):
        super().__init__(*args, **kw)
        self.server_opt = optim.yogi(lr=server_lr)
        self.server_opt_state = self.server_opt.init(self.params)

    def train_round(self, r: int, participants: list[int]) -> float:
        times = [self._full_model_time(k, self.clients[k].n_batches)
                 for k in participants]
        avg = self._train_round_full(r, participants)
        pseudo_grad = jax.tree.map(lambda g, l: g - l, self.params, avg)
        self.params, self.server_opt_state = self.server_opt.update(
            self.params, pseudo_grad, self.server_opt_state
        )
        return max(times)
