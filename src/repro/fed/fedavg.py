"""FedAvg (McMahan et al. 2017): full-model local training + weighted average.

Every client trains the whole global model — the paper's point is that the
straggler (slowest full-model client) bounds the round, which DTFL avoids.
"""
from __future__ import annotations

from repro.fed.base import BaseTrainer


class FedAvgTrainer(BaseTrainer):
    name = "fedavg"

    def train_round(self, r: int, participants: list[int]) -> float:
        self.params = self._train_round_full(r, participants)
        return max(self._full_model_time(k, self.clients[k].n_batches)
                   for k in participants)
