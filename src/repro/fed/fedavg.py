"""FedAvg (McMahan et al. 2017): full-model local training + weighted average.

Every client trains the whole global model — the paper's point is that the
straggler (slowest full-model client) bounds the round, which DTFL avoids.
"""
from __future__ import annotations

from repro.core import aggregation
from repro.fed.base import BaseTrainer


class FedAvgTrainer(BaseTrainer):
    name = "fedavg"

    def train_round(self, r: int, participants: list[int]) -> float:
        locals_, weights, times = [], [], []
        for k in participants:
            p = self._local_full_steps(r, k, self.params)
            locals_.append(p)
            weights.append(len(self.clients[k].dataset))
            times.append(self._full_model_time(k, self.clients[k].n_batches))
        self.params = aggregation.weighted_average(locals_, weights)
        return max(times)
