"""FedAvg (McMahan et al. 2017): full-model local training + weighted average.

Every client trains the whole global model — the paper's point is that the
straggler (slowest full-model client) bounds the round, which DTFL avoids.
FedAvg is exactly the BaseTrainer hook defaults: all participants train,
completion offsets are full-model times, aggregation is the N_k/N average.
"""
from __future__ import annotations

from repro.fed.base import BaseTrainer


class FedAvgTrainer(BaseTrainer):
    name = "fedavg"
