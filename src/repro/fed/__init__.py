"""Federated trainers: DTFL + the paper's baselines + the event engine."""
from repro.fed.adapter import ResNetAdapter, TransformerAdapter  # noqa: F401
from repro.fed.client import ChurnModel, HeteroEnv, SimClient  # noqa: F401
from repro.fed.dtfl import DTFLTrainer  # noqa: F401
from repro.fed.engine import RoundLog, RoundPlan  # noqa: F401
from repro.fed.execplan import ExecPlan  # noqa: F401
from repro.fed.fedat import FedATTrainer  # noqa: F401
from repro.fed.fedavg import FedAvgTrainer  # noqa: F401
from repro.fed.fedgkt import FedGKTTrainer  # noqa: F401
from repro.fed.fedyogi import FedYogiTrainer  # noqa: F401
from repro.fed.splitfed import SplitFedTrainer  # noqa: F401
from repro.fed.tifl import TiFLTrainer  # noqa: F401
from repro.fed.dropstrag import DropStragglerTrainer  # noqa: F401

TRAINERS = {
    "dtfl": DTFLTrainer,
    "fedavg": FedAvgTrainer,
    "fedyogi": FedYogiTrainer,
    "splitfed": SplitFedTrainer,
    "fedgkt": FedGKTTrainer,
    "tifl": TiFLTrainer,
    "drop30": DropStragglerTrainer,
    "fedat": FedATTrainer,
}
