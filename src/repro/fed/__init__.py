"""Federated trainers: DTFL + the paper's baselines + the event engine."""
from repro.fed.adapter import ResNetAdapter, TransformerAdapter  # noqa: F401
from repro.fed.client import ChurnModel, HeteroEnv, SimClient  # noqa: F401
from repro.fed.dtfl import DTFLTrainer  # noqa: F401
from repro.fed.engine import RoundLog, RoundPlan  # noqa: F401
from repro.fed.execplan import ExecPlan  # noqa: F401
from repro.fed.fedat import FedATTrainer  # noqa: F401
from repro.fed.population import ClientStore, LazyHeteroEnv  # noqa: F401
from repro.fed.fedavg import FedAvgTrainer  # noqa: F401
from repro.fed.fedgkt import FedGKTTrainer  # noqa: F401
from repro.fed.fedyogi import FedYogiTrainer  # noqa: F401
from repro.fed.splitfed import SplitFedTrainer  # noqa: F401
from repro.fed.tifl import TiFLTrainer  # noqa: F401
from repro.fed.dropstrag import DropStragglerTrainer  # noqa: F401

# legacy name->class view of the trainer registry (repro/registry.py is the
# single source of truth; construct through repro.api.ExperimentSpec.build())
from repro import registry as _registry

TRAINERS = {name: _registry.trainers.load(name)
            for name in _registry.trainers.names()}
