"""The DTFL training loop (Algorithm 1 MainServer, end to end).

Per round:
  1. TierScheduler assigns every participant a tier (dynamic, from observed
     times) — or a StaticScheduler for the Table-1 ablations.
  2. Each client trains (client-side + aux) on its local data while the
     server trains the client's server-side model on the uploaded z — both
     inside one jitted step per tier (compiled once, cached).
  3. Simulated wall-times per client come from the analytic time model and
     the client's ground-truth resource profile; the scheduler only observes
     the resulting times (+ the client-reported nu), as in the paper.
  4. Halves are merged and FedAvg'd with weights N_k/N; per-tier aux heads
     are averaged within their tier cohort.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, timemodel
from repro.core.scheduler import DynamicTierScheduler, StaticScheduler, TierProfile
from repro.fed.adapter import DTFLStepState
from repro.fed.client import HeteroEnv, SimClient


@dataclass
class RoundLog:
    round: int
    clock: float
    acc: float
    assignment: dict[int, int]
    straggler: float


class DTFLTrainer:
    def __init__(
        self,
        adapter,
        clients: list[SimClient],
        env: HeteroEnv,
        optimizer,
        *,
        scheduler: str | int = "dynamic",
        seed: int = 0,
        local_epochs: int = 1,
        server_flops: float = timemodel.SERVER_FLOPS,
    ):
        self.adapter = adapter
        self.clients = clients
        self.env = env
        self.opt = optimizer
        self.local_epochs = local_epochs
        self.server_flops = server_flops
        self.key = jax.random.PRNGKey(seed)
        self.params = adapter.init_global(self._next_key())
        self.costs = adapter.tier_costs(clients[0].dataset.batch_size)
        profile = TierProfile.from_cost_table(
            self.costs,
            clients[0].n_batches,
            ref_flops=timemodel.UNIT_FLOPS,
            server_flops=server_flops,
        )
        if scheduler == "dynamic":
            self.sched = DynamicTierScheduler(profile, len(clients))
        elif isinstance(scheduler, str) and scheduler.startswith("dynamic:"):
            m = int(scheduler.split(":")[1])  # M-tier deployment (Table 11)
            allowed = list(range(adapter.n_tiers))[-m:]
            self.sched = DynamicTierScheduler(profile, len(clients), allowed=allowed)
        else:
            self.sched = StaticScheduler(int(scheduler), len(clients))
        # per-tier aux heads, persistent and aggregated within tier cohorts
        self.aux = {
            m: adapter.aux_init(self._next_key(), m) for m in range(adapter.n_tiers)
        }
        self._step_cache: dict[int, callable] = {}

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _tier_step(self, tier: int):
        if tier not in self._step_cache:
            ad, opt = self.adapter, self.opt

            @jax.jit
            def step(state: DTFLStepState, batch: dict):
                (closs, z), (cg, ag) = jax.value_and_grad(
                    lambda cp, ap: ad.client_loss(cp, ap, batch), argnums=(0, 1),
                    has_aux=True,
                )(state.client, state.aux)
                z = jax.lax.stop_gradient(z)
                sloss, sg = jax.value_and_grad(
                    lambda sp: ad.server_loss(sp, z, batch, tier)
                )(state.server)
                c, co = opt.update(state.client, cg, state.c_opt)
                a, ao = opt.update(state.aux, ag, state.a_opt)
                s, so = opt.update(state.server, sg, state.s_opt)
                return DTFLStepState(c, a, s, co, ao, so), (closs, sloss)

            self._step_cache[tier] = step
        return self._step_cache[tier]

    # ------------------------------------------------------------------
    def train_round(self, r: int, participants: list[int]) -> tuple[float, dict[int, int]]:
        self.env.maybe_switch(r)
        assign = self.sched.schedule(participants)
        merged, weights, times = [], [], []
        for k in participants:
            tier = assign[k]
            cl = self.clients[k]
            cp, sp = self.adapter.split(self.params, tier)
            state = DTFLStepState(
                cp, self.aux[tier], sp,
                self.opt.init(cp), self.opt.init(self.aux[tier]), self.opt.init(sp),
            )
            step = self._tier_step(tier)
            for e in range(self.local_epochs):
                for batch in cl.dataset.epoch(r * 131 + e):
                    batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
                    state, _ = step(state, batch)
            self.aux[tier] = state.aux
            merged.append(self.adapter.merge(state.client, state.server))
            weights.append(len(cl.dataset))
            t = timemodel.simulate_client_times(
                self.costs, tier, self.env.profile(k), cl.n_batches,
                server_flops=self.server_flops, n_sharing=len(participants),
            )
            times.append(t["total"])
            self.sched.observe(
                k, tier=tier, total_client_time=t["client"] + t["comm"],
                nu=self.env.profile(k).bytes_per_s, n_batches=cl.n_batches,
            )
        self.params = aggregation.weighted_average(merged, weights)
        # aggregate aux heads within tier cohorts
        by_tier: dict[int, list[int]] = {}
        for k in participants:
            by_tier.setdefault(assign[k], []).append(k)
        return max(times), assign

    # ------------------------------------------------------------------
    # checkpointing (server state: global params + per-tier aux heads +
    # scheduler EMA history)
    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        from repro import checkpoint as ckpt
        from repro.core.scheduler import DynamicTierScheduler

        state = {"params": self.params,
                 "aux": {str(k): v for k, v in self.aux.items()}}
        if isinstance(self.sched, DynamicTierScheduler):
            import numpy as np

            ema_t, ema_v = [], []
            for cid, cl in enumerate(self.sched.clients):
                for tier, ema in cl.ema.items():
                    ema_t.append([cid, tier])
                    ema_v.append(ema.value)
            state["sched"] = {
                "tiers": np.array([c.tier for c in self.sched.clients]),
                "nu": np.array([c.nu for c in self.sched.clients]),
                "nb": np.array([c.n_batches for c in self.sched.clients]),
                "obs": np.array([-1 if c.last_obs_tier is None else c.last_obs_tier
                                 for c in self.sched.clients]),
                "ema_keys": np.array(ema_t or [[0, 0]][:0]).reshape(-1, 2),
                "ema_vals": np.array(ema_v),
            }
        ckpt.save(path, state)

    def restore(self, path: str) -> None:
        from repro import checkpoint as ckpt
        from repro.core.scheduler import EMA, DynamicTierScheduler

        state = ckpt.load(path)
        self.params = state["params"]
        self.aux = {int(k): v for k, v in state["aux"].items()}
        if "sched" in state and isinstance(self.sched, DynamicTierScheduler):
            sc = state["sched"]
            for cid, cl in enumerate(self.sched.clients):
                cl.tier = int(sc["tiers"][cid])
                cl.nu = float(sc["nu"][cid])
                cl.n_batches = int(sc["nb"][cid])
                obs = int(sc["obs"][cid])
                cl.last_obs_tier = None if obs < 0 else obs
            for (cid, tier), v in zip(sc["ema_keys"], sc["ema_vals"]):
                e = EMA()
                e.value = float(v)
                self.sched.clients[int(cid)].ema[int(tier)] = e

    # ------------------------------------------------------------------
    def run(
        self,
        n_rounds: int,
        eval_batch: dict,
        *,
        target_acc: float | None = None,
        participation: float = 1.0,
        eval_every: int = 1,
        verbose: bool = False,
        checkpoint_path: str | None = None,
        checkpoint_every: int = 10,
    ) -> list[RoundLog]:
        rng = np.random.default_rng(0)
        eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        eval_fn = jax.jit(self.adapter.eval_acc)
        clock, logs = 0.0, []
        n_part = max(1, int(participation * len(self.clients)))
        for r in range(n_rounds):
            participants = sorted(
                rng.choice(len(self.clients), n_part, replace=False).tolist()
            )
            straggler, assign = self.train_round(r, participants)
            clock += straggler
            acc = float(eval_fn(self.params, eval_batch)) if r % eval_every == 0 else (
                logs[-1].acc if logs else 0.0
            )
            logs.append(RoundLog(r, clock, acc, assign, straggler))
            if verbose:
                print(f"[dtfl] r={r} clock={clock:.0f}s acc={acc:.3f} tiers={sorted(set(assign.values()))}")
            if checkpoint_path and (r + 1) % checkpoint_every == 0:
                self.save(checkpoint_path)
            if target_acc is not None and acc >= target_acc:
                break
        if checkpoint_path:
            self.save(checkpoint_path)
        return logs
