"""The DTFL training loop (Algorithm 1 MainServer, end to end).

Per round:
  1. TierScheduler assigns every participant a tier (dynamic, from observed
     times) — or a StaticScheduler for the Table-1 ablations.
  2. Each tier's participants train as ONE vectorized cohort (fed.cohort):
     client-side + aux training and the server-side training on the uploaded
     z run inside a single jitted vmap+scan program per tier — O(n_tiers)
     dispatches per round. The trainer's :class:`~repro.fed.execplan.ExecPlan`
     picks the execution plane: ``cohort`` (single device), ``sharded``
     (client axis split over a device mesh, psum aggregation), or ``loop``
     (per-client sequential debug path).
  3. Simulated wall-times per client come from the analytic time model and
     the client's ground-truth resource profile (vectorized over the round);
     the scheduler only observes the resulting times (+ the client-reported
     nu), as in the paper.
  4. Halves are merged and FedAvg'd with weights N_k/N; per-tier aux heads
     start each round from the tier's shared head and are weight-averaged
     within their tier cohort afterwards (both execution paths).
  5. A wire :class:`~repro.core.codec.Codec` (``codec=`` / ``--codec``)
     compresses the three wires inside the jitted programs — activation
     uplink z, client-model download, client-update upload (delta-coded,
     with client-held error feedback for top-k) — and its TRUE byte counts
     drive both the simulated times and the scheduler's profile, so
     re-tiering reacts to the compressed compute/comm balance
     (docs/architecture.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, timemodel
from repro.core import codec as codec_lib
from repro.core import topology as topology_lib
from repro.core.scheduler import DynamicTierScheduler, StaticScheduler, TierProfile
from repro.data import pipeline
from repro.fed import cohort as cohort_engine
from repro.fed import engine as event_engine
from repro.fed.adapter import DTFLStepState
from repro.fed.client import HeteroEnv, SimClient
from repro.fed.engine import RoundLog, RoundPlan  # noqa: F401 (re-export)
from repro.fed.execplan import ExecPlan


class DTFLTrainer:
    name = "dtfl"

    def __init__(
        self,
        adapter,
        clients: list[SimClient],
        env: HeteroEnv,
        optimizer,
        *,
        scheduler: str | int = "dynamic",
        topology: str = "server",
        seed: int = 0,
        local_epochs: int = 1,
        server_flops: float = timemodel.SERVER_FLOPS,
        exec_plan: ExecPlan | str | None = None,
        codec: codec_lib.Codec | str | None = None,
    ):
        self.adapter = adapter
        self.clients = clients
        self.env = env
        self.opt = optimizer
        self.local_epochs = local_epochs
        self.server_flops = server_flops
        self.key = jax.random.PRNGKey(seed)
        self.params = adapter.init_global(self._next_key())
        self.costs = adapter.tier_costs(clients[0].dataset.batch_size)
        # communication plane: the codec compresses the three wires inside
        # the jitted programs AND prices them for the time model + scheduler
        self.codec = codec_lib.make_codec(codec)
        self.wires = codec_lib.wire_sizes(self.costs, self.codec)
        self._ef: dict[int, dict] = {}     # cid -> error-feedback residuals
        self.last_uplink_bytes = 0.0
        profile = TierProfile.from_cost_table(
            self.costs,
            ref_flops=timemodel.UNIT_FLOPS,
            server_flops=server_flops,
            wires=self.wires,
        )
        # scheduler specs resolve through the component registry, so
        # register_scheduler'd strategies work here with no trainer change
        from repro import registry

        if topology not in registry.topologies:
            registry.topologies.validate(topology)   # raises with choices
        if topology == "pairing" and scheduler == "dynamic":
            scheduler = "pairing"
        self.sched = registry.schedulers.build(
            scheduler, profile=profile, n_clients=len(clients),
            n_tiers=adapter.n_tiers)
        # the effective topology follows the scheduler: a host-providing
        # scheduler (pairing) activates peer offload, anything else is the
        # classic all-server topology
        provides_hosts = getattr(self.sched, "provides_hosts", False)
        if topology == "pairing" and not provides_hosts:
            raise ValueError(
                "topology='pairing' requires a host-providing scheduler "
                "(scheduler='pairing' or 'pairing:greedy'), got "
                f"{scheduler!r}")
        self.topology = "pairing" if provides_hosts else "server"
        self.last_hosts: dict[int, int] | None = None
        # per-tier aux heads, persistent and aggregated within tier cohorts
        self.aux = {
            m: adapter.aux_init(self._next_key(), m) for m in range(adapter.n_tiers)
        }
        # "loop" | "cohort" | "sharded[mesh]" — replaces the old cohort bool
        self.exec_plan = ExecPlan.resolve(exec_plan)
        self._step_cache: dict[int, callable] = {}
        self._cohort_cache: dict[int, callable] = {}
        self._sharded_cache: dict[int, callable] = {}

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _raw_step(self, tier: int):
        """Single-client DTFL step for ``tier`` (unjitted; shared by the
        sequential path and the vmapped cohort program). The activation
        uplink ``z`` is what actually crosses the network, so the codec
        round-trips it before the server loss (the client's own aux loss
        sees the uncompressed local activations)."""
        ad, opt, codec = self.adapter, self.opt, self.codec

        def step(state: DTFLStepState, batch: dict):
            (closs, z), (cg, ag) = jax.value_and_grad(
                lambda cp, ap: ad.client_loss(cp, ap, batch), argnums=(0, 1),
                has_aux=True,
            )(state.client, state.aux)
            z = codec.tree_rt(jax.lax.stop_gradient(z))
            sloss, sg = jax.value_and_grad(
                lambda sp: ad.server_loss(sp, z, batch, tier)
            )(state.server)
            c, co = opt.update(state.client, cg, state.c_opt)
            a, ao = opt.update(state.aux, ag, state.a_opt)
            s, so = opt.update(state.server, sg, state.s_opt)
            return DTFLStepState(c, a, s, co, ao, so), (closs, sloss)

        return step

    def _tier_step(self, tier: int):
        if tier not in self._step_cache:
            self._step_cache[tier] = jax.jit(self._raw_step(tier))
        return self._step_cache[tier]

    def _cohort_program(self, tier: int):
        """One jitted program per tier: split + optimizer init + vmapped scan
        over the cohort's steps + merge, all fused on device (eager per-leaf
        dispatch is exactly the overhead the engine removes).

        The codec's download wire round-trips (client half, tier aux head)
        before training and the upload wire round-trips each member's delta
        before the merge; stateful codecs additionally thread the per-client
        error-feedback residuals through the program."""
        if tier not in self._cohort_cache:
            ad, opt, codec = self.adapter, self.opt, self.codec
            step = self._raw_step(tier)

            def body(params, aux, batches, mask):
                cp, sp = ad.split(params, tier)
                cp, auxd = codec.tree_down_rt(cp), codec.tree_down_rt(aux)
                state = DTFLStepState(
                    cp, auxd, sp, opt.init(cp), opt.init(auxd), opt.init(sp)
                )
                final, _ = cohort_engine.run_cohort(step, state, batches, mask)
                return cp, auxd, final

            if codec.stateful:
                @jax.jit
                def run(params, aux, batches, mask, efc, efa):
                    cp, auxd, final = body(params, aux, batches, mask)
                    upc, efc2 = codec_lib.uplink_rt_ef(codec, final.client, cp, efc)
                    upa, efa2 = codec_lib.uplink_rt_ef(codec, final.aux, auxd, efa)
                    merged = jax.vmap(ad.merge)(upc, final.server)
                    return merged, upa, efc2, efa2
            else:
                @jax.jit
                def run(params, aux, batches, mask):
                    cp, auxd, final = body(params, aux, batches, mask)
                    upc = codec_lib.uplink_rt(codec, final.client, cp)
                    upa = codec_lib.uplink_rt(codec, final.aux, auxd)
                    merged = jax.vmap(ad.merge)(upc, final.server)
                    return merged, upa

            self._cohort_cache[tier] = run
        return self._cohort_cache[tier]

    def _sharded_program(self, tier: int):
        """The per-tier cohort program with its client axis split across the
        ExecPlan's mesh via shard_map. Each shard trains its client slice
        (split + opt init + vmapped scan + merge, exactly the cohort
        program), then the cross-client FedAvg weighted sums — merged global
        trees AND tier aux heads — reduce on-device as psum collectives;
        only (sum_tree, aux_sum_tree, weight_total) leave the mesh."""
        if tier not in self._sharded_cache:
            ad, opt, plan, codec = self.adapter, self.opt, self.exec_plan, self.codec
            step = self._raw_step(tier)

            def train_shard(params, aux, batches, mask):
                cp, sp = ad.split(params, tier)
                cp, auxd = codec.tree_down_rt(cp), codec.tree_down_rt(aux)
                state = DTFLStepState(
                    cp, auxd, sp, opt.init(cp), opt.init(auxd), opt.init(sp)
                )
                final, _ = cohort_engine.run_cohort(step, state, batches, mask)
                return cp, auxd, final

            if codec.stateful:
                def local(params, aux, batches, mask, weights, efc, efa):
                    cp, auxd, final = train_shard(params, aux, batches, mask)
                    upc, efc2 = codec_lib.uplink_rt_ef(codec, final.client, cp, efc)
                    upa, efa2 = codec_lib.uplink_rt_ef(codec, final.aux, auxd, efa)
                    merged = jax.vmap(ad.merge)(upc, final.server)
                    return (plan.psum_tree(merged, scaled_by=weights),
                            plan.psum_tree(upa, scaled_by=weights),
                            plan.psum_scalar(weights.sum()),
                            efc2, efa2)

                self._sharded_cache[tier] = jax.jit(plan.shard_cohort_call(
                    local, n_replicated=2, n_client_extra=2,
                    n_outs=5, client_outs=2,
                ))
            else:
                def local(params, aux, batches, mask, weights):
                    cp, auxd, final = train_shard(params, aux, batches, mask)
                    upc = codec_lib.uplink_rt(codec, final.client, cp)
                    upa = codec_lib.uplink_rt(codec, final.aux, auxd)
                    merged = jax.vmap(ad.merge)(upc, final.server)
                    return (plan.psum_tree(merged, scaled_by=weights),
                            plan.psum_tree(upa, scaled_by=weights),
                            plan.psum_scalar(weights.sum()))

                self._sharded_cache[tier] = jax.jit(
                    plan.shard_cohort_call(local, n_replicated=2)
                )
        return self._sharded_cache[tier]

    # ------------------------------------------------------------------
    # engine hooks (fed/engine.py contract): plan -> execute -> observe
    # ------------------------------------------------------------------
    def plan_round(self, r: int, participants: list[int]) -> RoundPlan:
        """Profile switching + Algorithm-1 scheduling + analytic Eq.-5 times.

        Pure planning: no parameter updates, no scheduler observations — the
        engine decides which planned clients actually report (churn)."""
        self.env.maybe_switch(r)
        # engine-side widening adapter: narrow cid->tier schedules (static /
        # dynamic) and generalized cid->(tier, host) schedules (pairing) both
        # become an OffloadTopology; plan.assign stays the narrow tier view
        # every downstream consumer (cohorts, EF, logs) uses
        topo = topology_lib.OffloadTopology.from_schedule(
            self.sched.schedule(participants))
        assign = topo.tiers()
        tiers = np.array([assign[k] for k in participants])
        profs = [self.env.profile(k) for k in participants]
        bps = np.array([p.bytes_per_s for p in profs])
        nb = np.array([self.clients[k].n_batches for k in participants])
        if topo.is_server_only:
            t = timemodel.simulate_client_times_batch(
                self.costs, tiers, np.array([p.flops for p in profs]), bps, nb,
                server_flops=self.server_flops, n_sharing=len(participants),
                wires=self.wires,
            )
            obs_nu = bps
        else:
            t = topology_lib.simulate_times(
                self.costs, topo, participants, profs, nb,
                server_flops=self.server_flops, wires=self.wires)
            obs_nu = t["link"]   # guests report the pair link, not their uplink
        # codec-true client->host bytes of this round (z uplink + update
        # upload), surfaced per round through RoundLog.uplink_bytes
        self.last_uplink_bytes = float(self.wires.uplink_bytes(tiers, nb).sum())
        self.last_hosts = (None if topo.is_server_only else
                           {k: h for k, h in topo.hosts().items()
                            if h != topology_lib.SERVER})
        return RoundPlan(
            participants=list(participants), trained=list(participants),
            assign=assign, times=t["total"],
            obs={"t": t["client"] + t["comm"], "nu": obs_nu, "nb": nb},
            topology=topo,
        )

    def execute_round(self, r: int, plan: RoundPlan, trained: list[int]) -> float:
        if not trained:
            return 0.0
        self.params = self._train_participants(r, trained, plan.assign)
        return 0.0

    def observe_round(self, plan: RoundPlan, idx: list[int], obs_times, totals) -> None:
        # contract (see fed/engine.py): obs_times is pre-sliced to idx;
        # plan.obs arrays are full-length and sliced here
        if not len(idx):
            return
        sel = np.asarray(idx, int)
        ks = [plan.trained[i] for i in idx]
        tiers = [plan.assign[k] for k in ks]
        self.sched.observe_cohort(
            ks, tiers, obs_times, plan.obs["nu"][sel], plan.obs["nb"][sel]
        )

    def train_group(self, r: int, plan: RoundPlan, trained: list[int]):
        """Async-tier hook: group-local training that returns the aggregated
        tree (per-tier aggregation) instead of committing it, so the async
        merger can staleness-weight it across tiers."""
        tree = self._train_participants(r, trained, plan.assign)
        return tree, float(sum(len(self.clients[k].dataset) for k in trained))

    def _train_participants(self, r, participants, assign):
        """ExecPlan dispatch: loop | cohort | sharded | chunked."""
        mode = self.exec_plan.mode
        if mode == "loop":
            return self._train_sequential(r, participants, assign)
        if mode == "sharded":
            return self._train_sharded(r, participants, assign)
        if mode == "chunked":
            return self._train_chunked(r, participants, assign)
        return self._train_cohorts(r, participants, assign)

    def async_groups(self, cids: list[int], n_groups: int) -> list[list[int]]:
        """Speed groups from the SCHEDULER's estimates (never ground truth):
        min-over-allowed-tiers T_hat, ascending — fast group first. A static
        scheduler has no estimates; its groups are contiguous slices."""
        if isinstance(self.sched, StaticScheduler):
            order = list(cids)
        else:
            sel = np.array(self.sched.allowed)
            est = self.sched.estimate_matrix(list(cids))[:, sel].min(axis=1)
            order = [cids[i] for i in np.argsort(est, kind="stable")]
        return event_engine.split_speed_groups(order, n_groups)

    # ------------------------------------------------------------------
    def train_round(self, r: int, participants: list[int]) -> tuple[float, dict[int, int]]:
        """Legacy scalar-clock round: plan -> execute(all) -> observe(all)."""
        plan = self.plan_round(r, participants)
        self.execute_round(r, plan, plan.trained)
        self.observe_round(plan, list(range(len(plan.trained))), plan.obs["t"], plan.times)
        return float(plan.times.max()), plan.assign

    def _train_cohorts(self, r, participants, assign):
        """O(n_tiers) device programs: one vmap+scan per (tier, shape) cohort.
        Returns the N_k/N aggregated global tree; updates per-tier aux heads."""
        merged_trees, merged_ws = [], []
        aux_by_tier: dict[int, list] = {}
        cohorts = cohort_engine.build_cohorts(
            self.clients, participants, assign, r, self.local_epochs
        )
        for co in cohorts:
            if self.codec.stateful:
                efc, efa = self._gather_ef(co)
                merged, aux, efc2, efa2 = self._cohort_program(co.tier)(
                    self.params, self.aux[co.tier], co.batches, co.mask,
                    efc, efa,
                )
                self._scatter_ef(co, efc2, efa2)
            else:
                merged, aux = self._cohort_program(co.tier)(
                    self.params, self.aux[co.tier], co.batches, co.mask
                )
            w = [len(self.clients[k].dataset) for k in co.cids]
            merged_trees.append(merged)
            merged_ws.append(w)
            aux_by_tier.setdefault(co.tier, []).append((aux, w))
        for tier, parts in aux_by_tier.items():
            self.aux[tier] = aggregation.weighted_average_cohorts(
                [a for a, _ in parts], [w for _, w in parts]
            )
        return aggregation.weighted_average_cohorts(merged_trees, merged_ws)

    def _train_sharded(self, r, participants, assign):
        """The cohort round with every cohort's client axis sharded over the
        ExecPlan mesh. Cohorts pad to a multiple of the mesh axis (zero
        batches, all-False mask, weight 0 — exact no-ops); each per-tier
        program returns psum-reduced weighted sums, and the host only
        combines one (sum, total) pair per cohort — identical math to
        ``_train_cohorts``'s stacked aggregation, so a 1-device mesh is
        bit-equal and an N-device mesh differs only by collective order."""
        sums, totals = [], []
        aux_by_tier: dict[int, list] = {}
        cohorts = cohort_engine.build_cohorts(
            self.clients, participants, assign, r, self.local_epochs,
            pad_multiple=self.exec_plan.pad_multiple,
        )
        for co in cohorts:
            w = co.client_weights(self.clients)
            if self.codec.stateful:
                efc, efa = self._gather_ef(co)
                msum, asum, wtot, efc2, efa2 = self._sharded_program(co.tier)(
                    self.params, self.aux[co.tier], co.batches, co.mask, w,
                    efc, efa,
                )
                self._scatter_ef(co, efc2, efa2)
            else:
                msum, asum, wtot = self._sharded_program(co.tier)(
                    self.params, self.aux[co.tier], co.batches, co.mask, w
                )
            sums.append(msum)
            totals.append(wtot)
            aux_by_tier.setdefault(co.tier, []).append((asum, wtot))
        for tier, parts in aux_by_tier.items():
            self.aux[tier] = aggregation.combine_weighted_sums(
                [a for a, _ in parts], [t for _, t in parts], like=self.aux[tier]
            )
        return aggregation.combine_weighted_sums(sums, totals, like=self.params)

    def _train_chunked(self, r, participants, assign):
        """The cohort round with each cohort's client axis cut into
        ``exec_plan.chunk_size``-client chunks, each run through the SAME
        compiled per-tier cohort program at chunk width — so the device
        training working set (stacked batches, per-client optimizer states,
        activations) is O(chunk_size), not O(cohort), which is what lets a
        512-participant sample train on a small host. Per-chunk outputs are
        concatenated on the host, pad columns dropped, and the identical
        ``weighted_average_cohorts`` aggregation runs on the reassembled
        stack — equivalence with ``_train_cohorts`` is by construction
        (eager per-chunk invocations of the same program are bitwise equal
        to slices of the full-cohort vmap; a ``lax.scan`` over chunks is
        not — see ``ExecPlan``)."""
        cs = self.exec_plan.chunk_size
        merged_trees, merged_ws = [], []
        aux_by_tier: dict[int, list] = {}
        cohorts = cohort_engine.build_cohorts(
            self.clients, participants, assign, r, self.local_epochs,
            pad_multiple=cs,
        )
        for co in cohorts:
            prog = self._cohort_program(co.tier)
            mchunks, achunks = [], []
            for sl in cohort_engine.chunk_slices(co.mask.shape[1], cs):
                b, m = cohort_engine.slice_clients(co.batches, co.mask, sl)
                if self.codec.stateful:
                    cids_c = co.cids[sl.start:min(sl.stop, co.size)]
                    efc, efa = self._gather_ef_cids(cids_c, co.tier, pad_to=cs)
                    merged, upa, efc2, efa2 = prog(
                        self.params, self.aux[co.tier], b, m, efc, efa)
                    self._scatter_ef_cids(cids_c, co.tier, efc2, efa2)
                else:
                    merged, upa = prog(self.params, self.aux[co.tier], b, m)
                mchunks.append(jax.tree.map(np.asarray, merged))
                achunks.append(jax.tree.map(np.asarray, upa))
            n = co.size    # reassemble the cohort stack, drop pad columns
            cat = lambda *xs: np.concatenate(xs)[:n]
            merged_trees.append(jax.tree.map(cat, *mchunks))
            w = [len(self.clients[k].dataset) for k in co.cids]
            merged_ws.append(w)
            aux_by_tier.setdefault(co.tier, []).append(
                (jax.tree.map(cat, *achunks), w))
        for tier, parts in aux_by_tier.items():
            self.aux[tier] = aggregation.weighted_average_cohorts(
                [a for a, _ in parts], [w for _, w in parts]
            )
        return aggregation.weighted_average_cohorts(merged_trees, merged_ws)

    def _train_sequential(self, r, participants, assign):
        """Per-client loop (debug escape hatch; O(clients x batches) dispatches)."""
        round_aux = dict(self.aux)  # cohort members share the round-start head
        merged, weights = [], []
        aux_by_tier: dict[int, list] = {}
        for k in participants:
            tier = assign[k]
            cl = self.clients[k]
            cp, sp = self.adapter.split(self.params, tier)
            cp = self.codec.tree_down_rt(cp)                  # download wire
            auxd = self.codec.tree_down_rt(round_aux[tier])
            state = DTFLStepState(
                cp, auxd, sp,
                self.opt.init(cp), self.opt.init(auxd), self.opt.init(sp),
            )
            step = self._tier_step(tier)
            for e in range(self.local_epochs):
                for batch in cl.dataset.epoch(r * pipeline.ROUND_SEED_STRIDE + e):
                    batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
                    state, _ = step(state, batch)
            # upload wire (with error feedback for stateful codecs)
            efc = efa = None
            if self.codec.stateful:
                efc, efa = self._client_ef(k, tier)
            upc, efc2 = codec_lib.uplink_rt_one(self.codec, state.client, cp, efc)
            upa, efa2 = codec_lib.uplink_rt_one(self.codec, state.aux, auxd, efa)
            if self.codec.stateful:
                self._ef[k] = {
                    "tier": tier,
                    "c": jax.tree.map(np.asarray, efc2),
                    "a": jax.tree.map(np.asarray, efa2),
                }
            aux_by_tier.setdefault(tier, []).append((upa, len(cl.dataset)))
            merged.append(self.adapter.merge(upc, state.server))
            weights.append(len(cl.dataset))
        for tier, parts in aux_by_tier.items():
            self.aux[tier] = aggregation.weighted_average(
                [a for a, _ in parts], [w for _, w in parts]
            )
        return aggregation.weighted_average(merged, weights)

    # ------------------------------------------------------------------
    # error-feedback state (stateful codecs): residuals live host-side per
    # client, shaped like the client's CURRENT tier halves — a re-tiered
    # client's residual no longer matches its upload shapes and is reset
    # (the standard EF answer to a topology change)
    # ------------------------------------------------------------------
    def _client_ef(self, cid: int, tier: int):
        """This client's (client-half, aux) residuals for ``tier`` — zeros
        if it has none yet or was re-tiered since."""
        st = self._ef.get(cid)
        if st is not None and st["tier"] == tier:
            return st["c"], st["a"]
        cp, _ = self.adapter.split(self.params, tier)
        zero = lambda t: jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), t)
        return zero(cp), zero(self.aux[tier])

    def _gather_ef_cids(self, cids, tier: int, *, pad_to: int | None = None):
        """Stack ``cids``'s residuals along the client axis, zero-padded up
        to ``pad_to`` clients (chunk tails / sharded pad clients — zero
        residuals are exact EF no-ops for weight-0 members)."""
        pairs = [self._client_ef(k, tier) for k in cids]
        n_pad = 0 if pad_to is None else pad_to - len(pairs)
        if n_pad:
            if pairs:
                zc = jax.tree.map(np.zeros_like, pairs[0][0])
                za = jax.tree.map(np.zeros_like, pairs[0][1])
            else:
                cp, _ = self.adapter.split(self.params, tier)
                zero = lambda t: jax.tree.map(
                    lambda x: np.zeros(x.shape, x.dtype), t)
                zc, za = zero(cp), zero(self.aux[tier])
            pairs += [(zc, za)] * n_pad
        stack = lambda trees: jax.tree.map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *trees)
        return stack([c for c, _ in pairs]), stack([a for _, a in pairs])

    def _scatter_ef_cids(self, cids, tier: int, efc, efa) -> None:
        for i, cid in enumerate(cids):
            self._ef[cid] = {
                "tier": tier,
                "c": jax.tree.map(lambda x: np.asarray(x[i]), efc),
                "a": jax.tree.map(lambda x: np.asarray(x[i]), efa),
            }

    def _gather_ef(self, co):
        """Stack the cohort's residuals along the client axis (zeros for the
        sharded plane's pad clients)."""
        return self._gather_ef_cids(co.cids, co.tier, pad_to=co.size + co.n_pad)

    def _scatter_ef(self, co, efc, efa) -> None:
        self._scatter_ef_cids(co.cids, co.tier, efc, efa)

    # ------------------------------------------------------------------
    def compact(self, keep) -> None:
        """Drop per-client state — cached data clients, scheduler history,
        EF residuals — of clients outside ``keep`` (PERMANENT departures).
        The engines never call this: a transiently-offline churn client
        keeps its EMA/EF history so rejoining is bit-identical with or
        without the absence. A compacted client that returns restarts from
        the never-sampled state (data rebuilds bit-identically from the
        lazy factory; scheduler/EF state restarts from defaults)."""
        keep = set(int(k) for k in keep)
        if hasattr(self.clients, "compact"):
            self.clients.compact(keep)
        if hasattr(self.sched, "compact"):
            self.sched.compact(keep)
        self._ef = {c: st for c, st in self._ef.items() if c in keep}

    # ------------------------------------------------------------------
    # checkpointing (server state: global params + per-tier aux heads +
    # scheduler EMA history + jax RNG key + env profile state)
    # ------------------------------------------------------------------
    def save_state(self) -> dict:
        from repro.core.scheduler import DynamicTierScheduler

        state = {"params": self.params,
                 "aux": {str(k): v for k, v in self.aux.items()},
                 "key": np.asarray(self.key),
                 "env": self.env.save_state()}
        if isinstance(self.sched, DynamicTierScheduler):
            # sparse: only TOUCHED clients ride the envelope (untouched ones
            # are pure defaults and rebuild lazily), so checkpoint size is
            # O(sampled participants) even for a 10^6-client registry
            items = self.sched.clients.touched_items()
            ema_t, ema_v = [], []
            for cid, cl in items:
                for tier, ema in cl.ema.items():
                    ema_t.append([cid, tier])
                    ema_v.append(ema.value)
            state["sched"] = {
                "cids": np.array([c for c, _ in items], dtype=np.int64),
                "tiers": np.array([cl.tier for _, cl in items], dtype=np.int64),
                "nu": np.array([cl.nu for _, cl in items], dtype=np.float64),
                "nb": np.array([cl.n_batches for _, cl in items], dtype=np.int64),
                "obs": np.array(
                    [-1 if cl.last_obs_tier is None else cl.last_obs_tier
                     for _, cl in items], dtype=np.int64),
                "ema_keys": np.array(ema_t or [[0, 0]][:0]).reshape(-1, 2),
                "ema_vals": np.array(ema_v),
            }
            if getattr(self.sched, "provides_hosts", False):
                # pairing topology: the latest guest->host map rides the
                # envelope so --resume re-enters the same offload topology
                hosts = self.sched.last_hosts
                state["sched"]["host_cids"] = np.array(
                    sorted(hosts), dtype=np.int64)
                state["sched"]["host_of"] = np.array(
                    [hosts[c] for c in sorted(hosts)], dtype=np.int64)
        if self.codec.stateful:
            # error-feedback residuals ride the envelope so --resume
            # continues the compressed-upload stream bit-deterministically
            state["ef"] = {
                str(cid): {"tier": np.int64(st["tier"]),
                           "c": st["c"], "a": st["a"]}
                for cid, st in self._ef.items()
            }
        return state

    def load_state(self, state: dict) -> None:
        from repro.core.scheduler import EMA, DynamicTierScheduler

        self.params = state["params"]
        self.aux = {int(k): v for k, v in state["aux"].items()}
        if "key" in state:
            self.key = jnp.asarray(state["key"])
        if "env" in state:
            self.env.load_state(state["env"])
        if "sched" in state and isinstance(self.sched, DynamicTierScheduler):
            sc = state["sched"]
            if "cids" in sc:
                # sparse envelope: reset to all-default, then replay the
                # touched clients — untouched ids stay lazy defaults
                self.sched.clients.compact([])
                cids = [int(c) for c in np.asarray(sc["cids"]).reshape(-1)]
            else:
                # legacy dense envelope (one entry per registered client)
                cids = list(range(len(np.asarray(sc["tiers"]).reshape(-1))))
            self.sched._rows.clear()
            for i, cid in enumerate(cids):
                cl = self.sched.clients[cid]
                cl.tier = int(sc["tiers"][i])
                cl.nu = float(sc["nu"][i])
                cl.n_batches = int(sc["nb"][i])
                obs = int(sc["obs"][i])
                cl.last_obs_tier = None if obs < 0 else obs
            for (cid, tier), v in zip(sc["ema_keys"], sc["ema_vals"]):
                e = EMA()
                e.value = float(v)
                self.sched.clients[int(cid)].ema[int(tier)] = e
            if "host_cids" in sc and getattr(self.sched, "provides_hosts",
                                            False):
                self.sched.last_hosts = {
                    int(c): int(h)
                    for c, h in zip(np.asarray(sc["host_cids"]).reshape(-1),
                                    np.asarray(sc["host_of"]).reshape(-1))}
        if "ef" in state:
            self._ef = {
                int(cid): {"tier": int(st["tier"]), "c": st["c"], "a": st["a"]}
                for cid, st in state["ef"].items()
            }

    def save(self, path: str) -> None:
        from repro import checkpoint as ckpt

        ckpt.save(path, self.save_state())

    def restore(self, path: str) -> None:
        """Load trainer state from ``path`` — either a bare ``save()`` state
        or a ``fed.engine.save_train_state`` resume envelope (unwrapped)."""
        event_engine.restore_trainer(self, path)

    # ------------------------------------------------------------------
    def run(
        self,
        n_rounds: int,
        eval_batch: dict,
        *,
        target_acc: float | None = None,
        participation: float = 1.0,
        sample_size: int | None = None,
        eval_every: int = 1,
        verbose: bool = False,
        checkpoint_path: str | None = None,
        checkpoint_every: int = 10,
        engine: str = "rounds",
        churn=None,
        n_groups: int = 3,
        resume: dict | None = None,
    ) -> list[RoundLog]:
        common = dict(
            target_acc=target_acc, participation=participation,
            eval_every=eval_every, verbose=verbose,
            checkpoint_path=checkpoint_path, checkpoint_every=checkpoint_every,
            resume=resume,
        )
        if engine == "events":
            return event_engine.run_events(
                self, n_rounds, eval_batch, churn=churn,
                sample_size=sample_size, **common)
        if engine == "async":
            if sample_size is not None:
                raise ValueError("sample_size is a rounds/events knob; the "
                                 "async engine groups the full population")
            return event_engine.run_async(
                self, n_rounds, eval_batch, churn=churn, n_groups=n_groups,
                **common)
        if engine != "rounds":
            raise ValueError(f"unknown engine {engine!r}")
        return event_engine.run_rounds(
            self, n_rounds, eval_batch, sample_size=sample_size, **common)
