"""FedAT-style asynchronous tier federation (Chai et al. 2021, arXiv:2010.05958).

Clients are profiled into speed tiers (like TiFL), but instead of selecting
ONE tier per synchronous round, every tier paces itself: a tier aggregates
its members' full-model updates as soon as its own straggler finishes
(**intra-tier synchronous**), and the server folds the fresh tier model into
the global model with a **staleness-weighted cross-tier merge** — tiers that
reported long ago count less. Fast tiers therefore contribute many updates
while a slow tier completes one, which is exactly the wall-clock win the
async timeline benchmark (``benchmarks/fig_async_timeline.py``) measures.

Implementation: the generic async event loop in ``fed/engine.py``
(:func:`repro.fed.engine.run_async`) drives the hook defaults from
``BaseTrainer`` — ``async_groups`` (speed profiling), ``train_group``
(per-tier cohort training + N_k/N aggregation), and the engine's
staleness-weighted merge. ``n_rounds`` is a per-tier wave budget; the merge
budget is ``n_rounds * n_groups``.
"""
from __future__ import annotations

from repro.fed.base import BaseTrainer


class FedATTrainer(BaseTrainer):
    name = "fedat"

    def __init__(self, *args, n_groups: int = 3, staleness_lambda: float = 1.0, **kw):
        super().__init__(*args, **kw)
        self.n_groups = n_groups
        self.staleness_lambda = staleness_lambda

    def run(self, n_rounds, eval_batch, *, engine: str = "async", n_groups=None, **kw):
        """FedAT is async by construction; ``engine`` is overridable only for
        debugging (``rounds`` degenerates to FedAvg with FedAT's grouping)."""
        if engine == "async":
            from repro.fed import engine as event_engine

            return event_engine.run_async(
                self, n_rounds, eval_batch,
                n_groups=n_groups or self.n_groups,
                staleness_lambda=self.staleness_lambda,
                **kw,
            )
        return super().run(n_rounds, eval_batch, engine=engine, **kw)
