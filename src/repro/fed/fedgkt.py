"""FedGKT (He et al. 2020a): group knowledge transfer.

Clients train only a SMALL model (client-side feature extractor + aux head)
with CE + KD against the server's logits; the server trains the LARGE
server-side model on uploaded features with CE + KD against client logits.

  phase 1: client local training (CE + KD vs last round's server logits)
  phase 2: upload (z, y, client_logits); server trains on all clients' z
           (CE + KD vs client logits) and produces fresh server logits,
           which clients use as the teacher next round.

Client-side split is fixed at md2 (He et al.'s small edge model). Round time
= max_k(client phase) + server phase — the phases are sequential, which is
why FedGKT trails DTFL in the paper's Table 3 despite small client models.
In engine terms the server phase is the round's *extra* serial time
(``execute_round``'s return value), appended after the last completion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.core.local_loss import token_xent
from repro.data import pipeline
from repro.fed.base import BaseTrainer, RoundPlan, kd_loss

SPLIT_TIER = 1
KD_WEIGHT = 0.5


class FedGKTTrainer(BaseTrainer):
    name = "fedgkt"
    supports_async = False  # algorithm lives outside train_group
    supports_codec = False  # bespoke (z, y, logits) KD protocol, not the
                            # codec plane's download/update-upload wires

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        cp, sp = self.adapter.split(self.params, SPLIT_TIER)
        self.client_params = cp            # shared (FedAvg'd) edge model
        self.server_params = sp            # single large server model
        self.aux = self.adapter.aux_init(self._next_key(), SPLIT_TIER)
        self.server_opt_state = self.opt.init(sp)
        self._teacher: dict[tuple[int, int], jax.Array] = {}  # (cid,batch) -> logits

    # ------------------------------------------------------------------
    def _steps(self):
        if hasattr(self, "_cstep"):
            return self._cstep, self._sstep
        ad, opt = self.adapter, self.opt

        @jax.jit
        def cstep(cp, ap, co, ao, batch, teacher, use_kd):
            def loss_fn(cp, ap):
                z = ad.client_features(cp, batch)
                logits = ad.aux_logits(ap, z)
                ce = token_xent(logits, batch["labels"], weight=batch.get("mask"))
                kd = jnp.where(
                    use_kd, kd_loss(logits, teacher, weight=batch.get("mask")), 0.0)
                return ce + KD_WEIGHT * kd, (z, logits)

            (_, (z, logits)), (cg, ag) = jax.value_and_grad(
                loss_fn, (0, 1), has_aux=True
            )(cp, ap)
            cp, co = opt.update(cp, cg, co)
            ap, ao = opt.update(ap, ag, ao)
            return cp, ap, co, ao, z, logits

        @jax.jit
        def sstep(sp, so, z, batch, client_logits):
            def loss_fn(sp):
                logits = ad.server_logits(sp, z, SPLIT_TIER)
                ce = token_xent(logits, batch["labels"], weight=batch.get("mask"))
                return ce + KD_WEIGHT * kd_loss(
                    logits, client_logits, weight=batch.get("mask")), logits

            (_, logits), g = jax.value_and_grad(loss_fn, has_aux=True)(sp)
            sp, so = opt.update(sp, g, so)
            return sp, so, logits

        self._cstep, self._sstep = cstep, sstep
        return cstep, sstep

    # ------------------------------------------------------------------
    def client_time(self, k: int) -> float:
        """Small edge model + feature/logit upload (phase 1 only)."""
        prof = self.env.profile(k)
        nb = self.clients[k].n_batches
        m = SPLIT_TIER
        return (
            self.costs.client_flops[m] * nb * self.local_epochs / prof.flops
            + (self.costs.z_bytes[m] * nb + self.costs.client_param_bytes[m])
            / prof.bytes_per_s
        )

    def execute_round(self, r: int, plan: RoundPlan, trained: list[int]) -> float:
        """Two-phase KD protocol over the survivors; returns the serial
        server phase as the round's extra time."""
        if not trained:
            return 0.0
        cstep, sstep = self._steps()
        client_updates, weights, uploads = [], [], []
        for k in trained:
            cp, ap = self.client_params, self.aux
            co, ao = self.opt.init(cp), self.opt.init(ap)
            for e in range(self.local_epochs):
                for bi, batch in enumerate(self.clients[k].dataset.epoch(
                        r * pipeline.ROUND_SEED_STRIDE + e)):
                    batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
                    teacher = self._teacher.get((k, bi))
                    use_kd = teacher is not None
                    if teacher is None:
                        teacher = jnp.zeros(
                            batch["labels"].shape + (self.adapter.cfg.n_classes
                                                     if hasattr(self.adapter.cfg, "n_classes")
                                                     else self.adapter.cfg.vocab,),
                            jnp.float32,
                        )
                    cp, ap, co, ao, z, logits = cstep(
                        cp, ap, co, ao, batch, teacher, jnp.asarray(use_kd)
                    )
                    if e == self.local_epochs - 1:
                        uploads.append((k, bi, z, batch, logits))
            client_updates.append((cp, ap))
            weights.append(len(self.clients[k].dataset))
        # phase 2: server trains the large model on all uploaded features
        for k, bi, z, batch, logits in uploads:
            self.server_params, self.server_opt_state, s_logits = sstep(
                self.server_params, self.server_opt_state, z, batch, logits
            )
            self._teacher[(k, bi)] = s_logits
        server_time = (
            self.costs.server_flops[SPLIT_TIER] * len(uploads) / self.server_flops
        )
        self.client_params = aggregation.weighted_average(
            [c for c, _ in client_updates], weights
        )
        self.aux = aggregation.weighted_average([a for _, a in client_updates], weights)
        self.params = self.adapter.merge(self.client_params, self.server_params)
        return server_time

    # ------------------------------------------------------------------
    # FedGKT's model lives OUTSIDE self.params (edge model, server model,
    # aux head, server optimizer, per-(cid,batch) teacher-logit cache) —
    # without these a --resume would silently restart from fresh weights
    # ------------------------------------------------------------------
    def save_state(self) -> dict:
        state = super().save_state()
        state["fedgkt"] = {
            "client": self.client_params,
            "server": self.server_params,
            "aux": self.aux,
            "server_opt": self.server_opt_state,
            "teacher": {f"{c}:{b}": v for (c, b), v in self._teacher.items()},
        }
        return state

    def load_state(self, state: dict) -> None:
        super().load_state(state)
        if "fedgkt" in state:
            g = state["fedgkt"]
            self.client_params = g["client"]
            self.server_params = g["server"]
            self.aux = g["aux"]
            self.server_opt_state = g["server_opt"]
            self._teacher = {}
            for key, v in g["teacher"].items():
                c, b = key.split(":")
                self._teacher[(int(c), int(b))] = jnp.asarray(v)
