"""Straggler-dropping FedAvg (Bonawitz et al. 2019, discussed in the paper's
related work): each round waits only for the fastest (1 - drop_frac) of the
participants and discards the rest — fast rounds, but the slowest clients'
data never contributes, which hurts non-IID accuracy. Reference baseline
showing why DTFL's keep-everyone-via-offloading is the better trade.
"""
from __future__ import annotations

import numpy as np

from repro.fed.base import BaseTrainer


class DropStragglerTrainer(BaseTrainer):
    name = "drop30"

    def __init__(self, *args, drop_frac: float = 0.3, **kw):
        super().__init__(*args, **kw)
        self.drop_frac = drop_frac

    def train_round(self, r: int, participants: list[int]) -> float:
        times = {k: self._full_model_time(k, self.clients[k].n_batches)
                 for k in participants}
        keep_n = max(1, int(np.ceil(len(participants) * (1 - self.drop_frac))))
        kept = sorted(participants, key=lambda k: times[k])[:keep_n]
        self.params = self._train_round_full(r, kept)
        return max(times[k] for k in kept)
