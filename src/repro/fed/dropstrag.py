"""Straggler-dropping FedAvg (Bonawitz et al. 2019, discussed in the paper's
related work): each round waits only for the fastest (1 - drop_frac) of the
participants and discards the rest — fast rounds, but the slowest clients'
data never contributes, which hurts non-IID accuracy. Reference baseline
showing why DTFL's keep-everyone-via-offloading is the better trade.
"""
from __future__ import annotations

import numpy as np

from repro.fed.base import BaseTrainer


class DropStragglerTrainer(BaseTrainer):
    name = "drop30"
    supports_async = False  # algorithm lives outside train_group

    def __init__(self, *args, drop_frac: float = 0.3, **kw):
        super().__init__(*args, **kw)
        self.drop_frac = drop_frac

    def select_clients(self, r: int, participants: list[int]) -> list[int]:
        times = {k: self.client_time(k) for k in participants}
        keep_n = max(1, int(np.ceil(len(participants) * (1 - self.drop_frac))))
        return sorted(participants, key=lambda k: times[k])[:keep_n]
