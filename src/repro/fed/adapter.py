"""Model adapters: a uniform interface over the paper's ResNets and the
assigned transformer archs so every federated algorithm (DTFL + baselines)
is model-agnostic.

An adapter provides: global init, tier split/merge, the two DTFL local-loss
objectives, a monolithic objective, eval, and the per-tier cost table used by
both the time simulator and the scheduler's profiling.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import splitting, tiering, timemodel
from repro.core.local_loss import token_xent
from repro.models import model as M
from repro.models import resnet as R

Params = Any


class DTFLStepState(NamedTuple):
    client: Params
    aux: Params
    server: Params
    c_opt: Any
    a_opt: Any
    s_opt: Any


def _xent_logits(logits, labels, weight=None):
    # weight = the pad mask of fixed-shape partial batches (data/pipeline.py);
    # eval batches and LM batches carry no mask -> plain mean
    return token_xent(logits, labels, weight=weight)


def _acc(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


# ===========================================================================
# ResNet adapter (the paper's own models)
# ===========================================================================

class ResNetAdapter:
    def __init__(self, cfg, *, cost_cfg=None, dcor_alpha: float = 0.0,
                 patch_shuffle: bool = False):
        self.cfg = cfg
        # time model may price the full-size model; tier count must match
        cost_cfg = cost_cfg or cfg
        if cost_cfg.n_modules != cfg.n_modules:
            import dataclasses
            cost_cfg = dataclasses.replace(cost_cfg, n_modules=cfg.n_modules)
        self.cost_cfg = cost_cfg
        self.n_tiers = cfg.n_modules - 1
        self.dcor_alpha = dcor_alpha
        self.patch_shuffle = patch_shuffle

    def init_global(self, key) -> Params:
        return R.init(key, self.cfg)

    def split(self, params: Params, tier: int):
        # tier is 0-based here; paper tier m keeps modules md1..md{m+1}
        nb = R.n_blocks_in_modules(self.cfg, tier + 1)
        return splitting.split_params(params, nb, splitting.RESNET)

    def merge(self, client: Params, server: Params) -> Params:
        return splitting.merge_params(client, server, splitting.RESNET)

    def aux_init(self, key, tier: int) -> Params:
        return R.aux_init(key, self.cfg, tier + 1)

    # ---- losses ----
    def client_loss(self, cp: Params, ap: Params, batch: dict, rng=None):
        z = R.client_forward(cp, self.cfg, batch["images"])
        if self.patch_shuffle and rng is not None:
            from repro.privacy import patch_shuffle as ps

            zs = z.reshape(z.shape[0], -1, z.shape[-1])
            z_up = ps(rng, zs, 16).reshape(z.shape)
        else:
            z_up = z
        logits = R.aux_apply(ap, z)
        loss = _xent_logits(logits, batch["labels"], batch.get("mask"))
        if self.dcor_alpha > 0.0:
            from repro.privacy import dcor

            # note: dcor sees padded rows too (undersized clients only);
            # masking pairwise distances is not worth the regularizer's noise
            loss = (1 - self.dcor_alpha) * loss + self.dcor_alpha * dcor(
                batch["images"], z
            )
        return loss, z_up

    def server_loss(self, sp: Params, z: jax.Array, batch: dict, tier: int):
        logits = R.server_forward(sp, self.cfg, z, tier + 1)
        return _xent_logits(logits, batch["labels"], batch.get("mask"))

    def full_loss(self, params: Params, batch: dict):
        return _xent_logits(R.forward(params, self.cfg, batch["images"]),
                            batch["labels"], batch.get("mask"))

    def eval_acc(self, params: Params, batch: dict) -> jax.Array:
        return _acc(R.forward(params, self.cfg, batch["images"]), batch["labels"])

    # FedGKT hooks
    def client_features(self, cp: Params, batch: dict):
        return R.client_forward(cp, self.cfg, batch["images"])

    def aux_logits(self, ap: Params, z) -> jax.Array:
        return R.aux_apply(ap, z)

    def server_logits(self, sp: Params, z, tier: int) -> jax.Array:
        return R.server_forward(sp, self.cfg, z, tier + 1)

    def tier_costs(self, batch_size: int) -> timemodel.TierCostTable:
        return timemodel.resnet_tier_costs(self.cost_cfg, batch_size)


# ===========================================================================
# Transformer adapter (assigned archs)
# ===========================================================================

class TransformerAdapter:
    def __init__(self, cfg, *, seq_len: int, cost_cfg=None, dcor_alpha: float = 0.0):
        # DTFL split training unties embeddings (DESIGN.md): the halves live
        # on different hosts.
        self.cfg = cfg.replace(tie_embeddings=False)
        cost_cfg = (cost_cfg or cfg).replace(tie_embeddings=False)
        if cost_cfg.n_modules != self.cfg.n_modules:
            cost_cfg = cost_cfg.replace(n_modules=self.cfg.n_modules)
        self.cost_cfg = cost_cfg
        self.seq_len = seq_len
        self.n_tiers = tiering.n_tiers(self.cfg)
        self.dcor_alpha = dcor_alpha

    def init_global(self, key) -> Params:
        return M.init(key, self.cfg)

    def split(self, params: Params, tier: int):
        return tiering.split_params(params, self.cfg, tier + 1)

    def merge(self, client: Params, server: Params) -> Params:
        return tiering.merge_params(client, server)

    def aux_init(self, key, tier: int) -> Params:
        return M.aux_head_init(key, self.cfg)

    def client_loss(self, cp: Params, ap: Params, batch: dict, rng=None):
        z, moe_aux = M.client_forward(cp, self.cfg, batch)
        logits = M.aux_head_apply(ap, self.cfg, z)
        loss = _xent_logits(logits, batch["labels"], batch.get("mask")) + 0.01 * moe_aux
        if self.dcor_alpha > 0.0:
            from repro.privacy import dcor

            x_in = M.embed_tokens(cp, self.cfg, batch)
            zz = z[0] if isinstance(z, tuple) else z
            loss = (1 - self.dcor_alpha) * loss + self.dcor_alpha * dcor(x_in, zz)
        return loss, z

    def server_loss(self, sp: Params, z, batch: dict, tier: int):
        logits, moe_aux = M.server_forward(sp, self.cfg, z)
        return _xent_logits(logits, batch["labels"], batch.get("mask")) + 0.01 * moe_aux

    def full_loss(self, params: Params, batch: dict):
        logits, moe_aux = M.forward(params, self.cfg, batch)
        return _xent_logits(logits, batch["labels"], batch.get("mask")) + 0.01 * moe_aux

    def eval_acc(self, params: Params, batch: dict) -> jax.Array:
        logits, _ = M.forward(params, self.cfg, batch)
        return _acc(logits, batch["labels"])

    def tier_costs(self, batch_size: int) -> timemodel.TierCostTable:
        return timemodel.transformer_tier_costs(self.cost_cfg, batch_size, self.seq_len)

    # FedGKT hooks
    def client_features(self, cp: Params, batch: dict):
        z, _ = M.client_forward(cp, self.cfg, batch)
        return z

    def aux_logits(self, ap: Params, z) -> jax.Array:
        return M.aux_head_apply(ap, self.cfg, z)

    def server_logits(self, sp: Params, z, tier: int) -> jax.Array:
        logits, _ = M.server_forward(sp, self.cfg, z)
        return logits
