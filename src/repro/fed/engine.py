"""Event-driven federation engine over the discrete-event core.

``core/events.py`` supplies the virtual clock and deterministic event queue;
this layer turns a trainer's *round plan* into completion events, drains due
events back into (tier, shape) cohorts, and executes them through the
existing vectorized cohort programs (``fed/cohort.py``) — async semantics
cost no per-client dispatch.

Trainer contract (implemented by ``BaseTrainer`` and ``DTFLTrainer``):

  plan_round(r, participants) -> RoundPlan
      Profile switching + scheduling/selection + the analytic Eq.-5
      completion offset of every client that will train. Pure planning: no
      parameter updates, no scheduler observations.
  execute_round(r, plan, trained) -> float
      Train ``trained`` (the survivors) through the cohort programs and fold
      the result into the trainer's state. Returns extra *serial* simulated
      time appended after the last completion (e.g. FedGKT's server phase).
  observe_round(plan, idx, obs_times, totals)
      Feed the event-derived timestamps of the clients that actually
      reported back to the scheduler / speed profiler. Contract: ``idx``
      indexes into ``plan.trained``; ``obs_times`` and ``totals`` are
      ALREADY SLICED to ``idx`` (obs_times[j] belongs to
      plan.trained[idx[j]]) — per-participant plan arrays such as
      ``plan.obs['nu']`` are full-length and must be indexed with ``idx``.
  train_group(r, plan, trained) -> (tree, weight)     [async mode]
      Like execute_round but returns the group-aggregated parameter tree
      instead of committing it, so the async merger can staleness-weight it.
  async_groups(cids, n_groups) -> list[list[int]]     [async mode]
      Speed grouping (fast -> slow) for FedAT-style per-tier pacing.

Three run modes:

  * :func:`run_events` — **sync**: every round's completions drain before
    aggregation. Without churn this reproduces the legacy scalar-clock loop
    exactly (same participant sampling, same clock, same scheduler
    observations — equivalence-tested in ``tests/test_events.py``); with a
    :class:`~repro.fed.client.ChurnModel` it adds dropout / arrival /
    mid-round profile switches that the scalar loop cannot express.
  * :func:`run_async` — **async tiers**: clients are grouped by speed; each
    group paces itself, and every group completion triggers a per-tier
    aggregation plus a staleness-weighted cross-tier merge (FedAT,
    arXiv:2010.05958). Fast groups stop waiting for stragglers entirely.
  * the legacy ``rounds`` loop stays in the trainers as the scalar-clock
    reference path.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, timemodel
from repro.core.events import EventQueue


@dataclass
class RoundLog:
    round: int
    clock: float
    acc: float
    assignment: dict[int, int]
    straggler: float
    # codec-true client->server bytes planned for the round (z uplink +
    # update upload; fed/dtfl.py / fed/base.py set it in plan_round)
    uplink_bytes: float = 0.0
    # guest cid -> host cid under a peer-offload topology (core/topology.py);
    # None for the classic all-server topology, so server-mode logs are
    # unchanged field-for-field
    hosts: dict[int, int] | None = None


@dataclass
class RoundPlan:
    """A trainer's declarative plan for one round (or one async wave)."""

    participants: list[int]        # sampled participants
    trained: list[int]             # subset that actually computes (TiFL/drop30)
    assign: dict[int, int]         # cid -> tier (constant for full-model)
    times: np.ndarray              # (len(trained),) Eq.-5 completion offsets
    obs: dict | None = None        # scheduler observation arrays:
                                   #   t (client+comm), nu, nb — or None
    topology: object | None = None  # core.topology.OffloadTopology, or None
                                    # (classic all-server far-half placement)


def _plan_hosts(plan: RoundPlan) -> dict[int, int] | None:
    """Guest->host map for the round log; None when every far half runs on
    the server (keeps server-mode logs identical to the pre-topology path)."""
    topo = plan.topology
    if topo is None or topo.is_server_only:
        return None
    return {k: h for k, h in topo.hosts().items() if h != -1}


def split_speed_groups(order: list[int], n_groups: int) -> list[list[int]]:
    """Slice a fast->slow ordering into ``n_groups`` contiguous speed groups
    (the remainder joins the slowest group; fewer clients than groups yields
    fewer groups). Shared by every ``async_groups`` implementation so DTFL
    and the full-model baselines group identically."""
    cut = max(1, len(order) // n_groups)
    groups = [order[i * cut: (i + 1) * cut] for i in range(n_groups - 1)]
    groups.append(order[(n_groups - 1) * cut:])
    return [g for g in groups if g]


def _participants_rng() -> np.random.Generator:
    # the legacy loops draw participants from default_rng(0); the engine must
    # consume the identical stream for sync-mode equivalence
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# resumable training state (train.py --save-every / --out-ckpt / --resume)
# ---------------------------------------------------------------------------

# the rounds and events engines share round/clock/rng semantics, so their
# envelopes resume interchangeably; async envelopes count merges, not rounds
_SYNC_ENGINES = frozenset({"rounds", "events"})


def save_train_state(path: str, trainer, *, round_: int, clock: float,
                     rng: np.random.Generator | None = None,
                     acc: float = 0.0, engine: str = "rounds") -> None:
    """Checkpoint the FULL run state as one envelope: the trainer's state
    (params, per-tier aux heads, optimizer states, scheduler history, jax
    RNG key, env profile state) plus the loop cursor (next round, virtual
    clock, last evaluated accuracy — non-eval rounds carry it forward, so
    target_acc early-stops stay resume-invariant), the participant-sampling
    numpy rng stream, and the originating engine (async envelopes count
    merges, not rounds, and must not resume a sync loop) — everything a
    resumed run needs to continue bit-for-bit where it left off."""
    from repro import checkpoint as ckpt

    state = {"round": np.int64(round_), "clock": np.float64(clock),
             "acc": np.float64(acc), "engine": engine,
             "trainer": trainer.save_state()}
    if rng is not None:
        state["rng"] = ckpt.pack_rng(rng)
    # spec-built trainers (repro.api.Federation) stamp the envelope with the
    # experiment's identity hash + canonical JSON so resume can verify it is
    # continuing the SAME experiment
    stamp = getattr(trainer, "_spec_stamp", None)
    if stamp is not None:
        state["spec"] = dict(stamp)
    ckpt.save(path, state)


def apply_resume(trainer, resume: dict, rng: np.random.Generator,
                 *, engine: str) -> tuple[int, float, float]:
    """Restore a :func:`save_train_state` envelope into ``trainer`` and the
    caller's participant rng (mutated in place so the stream continues);
    returns (start_round, start_clock, last_acc). Rejects envelopes whose
    originating engine is incompatible with ``engine``."""
    from repro import checkpoint as ckpt

    src = str(resume["engine"]) if "engine" in resume else None
    if src is not None and not (src in _SYNC_ENGINES and engine in _SYNC_ENGINES):
        raise ValueError(
            f"checkpoint was written by engine={src!r}; it cannot resume a "
            f"run under engine={engine!r} (round counters and rng streams "
            "are engine-specific)")
    trainer.load_state(resume["trainer"])
    if "rng" in resume:
        rng.bit_generator.state = ckpt.unpack_rng(resume["rng"]).bit_generator.state
    return (int(resume["round"]), float(resume["clock"]),
            float(resume.get("acc", 0.0)))


def restore_trainer(trainer, path: str) -> None:
    """Load trainer state from ``path`` — a bare ``save_state()`` dump or a
    :func:`save_train_state` envelope (unwrapped). Shared by every
    trainer's ``restore``."""
    from repro import checkpoint as ckpt

    state = ckpt.load(path)
    trainer.load_state(state["trainer"] if "trainer" in state else state)


def _round_sample_size(n_clients: int, participation: float,
                       sample_size: int | None) -> int:
    """Participants per round. ``sample_size`` is the population plane's
    absolute count (a 512-sample round over a 10^6 registry); ``None``
    keeps the legacy fractional ``participation`` sizing bit-for-bit.
    ``Generator.choice(n, k, replace=False)`` is O(k) time and memory
    (Floyd's algorithm), so sampling never scales with the registry."""
    if sample_size is None:
        return max(1, int(participation * n_clients))
    if sample_size < 1:
        raise ValueError(f"sample_size must be >= 1, got {sample_size}")
    return min(int(sample_size), n_clients)


def run_rounds(
    trainer,
    n_rounds: int,
    eval_batch: dict,
    *,
    target_acc: float | None = None,
    participation: float = 1.0,
    sample_size: int | None = None,
    eval_every: int = 1,
    verbose: bool = False,
    checkpoint_path: str | None = None,
    checkpoint_every: int = 10,
    resume: dict | None = None,
) -> list[RoundLog]:
    """The legacy scalar-clock synchronous loop, shared by every trainer
    (``run(engine="rounds")``): sample participants, ``train_round``,
    accumulate the straggler clock, eval/log, checkpoint. DTFL's
    ``train_round`` returns ``(straggler, assign)``; full-model baselines
    return the bare straggler."""
    rng = _participants_rng()
    eval_fn, eval_batch = _eval_setup(trainer, eval_batch)
    clock, logs = 0.0, []
    start_round, last_acc = 0, 0.0
    if resume is not None:
        start_round, clock, last_acc = apply_resume(
            trainer, resume, rng, engine="rounds")
    next_round = start_round
    n_part = _round_sample_size(len(trainer.clients), participation, sample_size)
    for r in range(start_round, n_rounds):
        participants = sorted(
            rng.choice(len(trainer.clients), n_part, replace=False).tolist()
        )
        res = trainer.train_round(r, participants)
        straggler, assign = res if isinstance(res, tuple) else (res, {})
        clock += straggler
        acc = float(eval_fn(trainer.params, eval_batch)) if r % eval_every == 0 else (
            logs[-1].acc if logs else last_acc)
        logs.append(RoundLog(r, clock, acc, assign, straggler,
                             uplink_bytes=getattr(trainer, "last_uplink_bytes", 0.0),
                             hosts=getattr(trainer, "last_hosts", None)))
        next_round = r + 1
        if verbose:
            tiers = f" tiers={sorted(set(assign.values()))}" if assign else ""
            hosts = logs[-1].hosts
            pairs = f" pairs={sorted(hosts.items())}" if hosts else ""
            print(f"[{trainer.name}] r={r} clock={clock:.0f}s acc={acc:.3f}"
                  f"{tiers}{pairs}")
        if checkpoint_path and (r + 1) % checkpoint_every == 0:
            save_train_state(checkpoint_path, trainer, round_=r + 1,
                             clock=clock, rng=rng, acc=acc)
        if target_acc is not None and acc >= target_acc:
            break
    if checkpoint_path:
        save_train_state(checkpoint_path, trainer, round_=next_round,
                         clock=clock, rng=rng,
                         acc=logs[-1].acc if logs else last_acc)
    return logs


def _eval_setup(trainer, eval_batch):
    eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
    # cache the jitted eval on the trainer: repeated run() calls (and sweep
    # grid points that adopt this trainer's compiled programs) must not
    # retrace a fresh jit wrapper per run
    fn = getattr(trainer, "_eval_jit", None)
    if fn is None:
        fn = jax.jit(trainer.adapter.eval_acc)
        trainer._eval_jit = fn
    return fn, eval_batch


# ===========================================================================
# sync mode: the legacy round loop as a degenerate event schedule
# ===========================================================================

def run_events(
    trainer,
    n_rounds: int,
    eval_batch: dict,
    *,
    target_acc: float | None = None,
    participation: float = 1.0,
    sample_size: int | None = None,
    eval_every: int = 1,
    verbose: bool = False,
    churn=None,
    checkpoint_path: str | None = None,
    checkpoint_every: int = 10,
    resume: dict | None = None,
) -> list[RoundLog]:
    rng = _participants_rng()
    eval_fn, eval_batch = _eval_setup(trainer, eval_batch)
    q = EventQueue()
    logs: list[RoundLog] = []
    n_clients = len(trainer.clients)

    start_round, last_acc = 0, 0.0
    if resume is not None:
        if churn is not None:
            raise ValueError("resume with churn is unsupported (the churn "
                             "model's offline/arrival state is not "
                             "checkpointed); restart without --churn")
        start_round, clock0, last_acc = apply_resume(
            trainer, resume, rng, engine="events")
        q.advance_to(clock0)
    next_round = start_round

    for r in range(start_round, n_rounds):
        # no churn: pass the population SIZE, not an arange — choice(int)
        # consumes the identical rng stream as choice(arange(int)) but stays
        # O(sample) instead of materializing an O(population) id array
        pool = churn.begin_round(r) if churn is not None else n_clients
        pool_n = len(pool) if churn is not None else n_clients
        cap = (int(participation * n_clients) if sample_size is None
               else _round_sample_size(n_clients, participation, sample_size))
        n_part = max(1, min(pool_n, cap))
        participants = sorted(rng.choice(pool, n_part, replace=False).tolist())

        plan = trainer.plan_round(r, participants)
        start = q.now
        # one completion event per trained client; payload carries the
        # planned offset so float identity survives absolute-time round trips
        pending: dict[int, object] = {}
        for i, k in enumerate(plan.trained):
            pending[i] = q.push(
                start + plan.times[i], "complete",
                cid=k, idx=i, offset=float(plan.times[i]),
            )
        if churn is not None:
            for kind, i, frac in churn.sample_mid_round(plan.trained, plan.times):
                q.push(start + frac * plan.times[i], kind,
                       cid=plan.trained[i], idx=i)

        # drain the round: completions, dropouts, mid-round switches
        survivors: list[int] = []
        offsets: dict[int, float] = {}
        while not q.empty():
            ev = q.pop()
            i = ev.payload["idx"]
            if ev.kind == "complete":
                survivors.append(i)
                offsets[i] = ev.payload["offset"]
            elif ev.kind == "dropout":
                if i in survivors:
                    continue  # completed before the dropout fired
                pending[i].cancel()
                churn.mark_offline(ev.payload["cid"])
            elif ev.kind == "switch":
                if i in survivors:
                    continue
                cid = ev.payload["cid"]
                old = trainer.env.profile(cid)
                churn.resample_profile(trainer.env, cid)
                new = trainer.env.profile(cid)
                new_off = timemodel.rescale_remaining(
                    pending[i].payload["offset"], ev.time - start, old, new
                )
                pending[i].cancel()
                pending[i] = q.push(
                    start + new_off, "complete",
                    cid=cid, idx=i, offset=float(new_off),
                )

        survivors.sort()
        trained = [plan.trained[i] for i in survivors]
        extra = trainer.execute_round(r, plan, trained) or 0.0

        if trained:
            ratios = np.array(
                [offsets[i] / plan.times[i] for i in survivors]
            )
            totals = np.array([offsets[i] for i in survivors])
            if plan.obs is not None:
                obs_t = plan.obs["t"][np.asarray(survivors, int)] * ratios
            else:
                obs_t = totals
            trainer.observe_round(plan, survivors, obs_t, totals)
            base = float(max(offsets[i] for i in survivors)) + extra
        else:
            base = extra  # everyone dropped
        # the server learns of a dropout at the dropout timestamp, so a round
        # never ends before the last drained event (q.now)
        round_end = max(q.now, start + base)
        straggler = round_end - start
        q.advance_to(round_end)

        acc = float(eval_fn(trainer.params, eval_batch)) if r % eval_every == 0 else (
            logs[-1].acc if logs else last_acc
        )
        logs.append(RoundLog(r, q.now, acc,
                             plan.assign if hasattr(trainer, "sched") else {},
                             straggler,
                             uplink_bytes=getattr(trainer, "last_uplink_bytes", 0.0),
                             hosts=_plan_hosts(plan)))
        next_round = r + 1
        if verbose:
            dropped = len(plan.trained) - len(trained)
            hosts = logs[-1].hosts
            print(f"[events:{trainer.name}] r={r} clock={q.now:.0f}s acc={acc:.3f}"
                  + (f" dropped={dropped}" if dropped else "")
                  + (f" pairs={sorted(hosts.items())}" if hosts else ""))
        if checkpoint_path and (r + 1) % checkpoint_every == 0:
            save_train_state(checkpoint_path, trainer, round_=r + 1,
                             clock=q.now, rng=rng, acc=acc, engine="events")
        if target_acc is not None and acc >= target_acc:
            break
    if checkpoint_path:
        save_train_state(checkpoint_path, trainer, round_=next_round,
                         clock=q.now, rng=rng,
                         acc=logs[-1].acc if logs else last_acc,
                         engine="events")
    return logs


# ===========================================================================
# async mode: FedAT-style per-tier pacing + staleness-weighted merge
# ===========================================================================

def run_async(
    trainer,
    n_rounds: int,
    eval_batch: dict,
    *,
    target_acc: float | None = None,
    participation: float = 1.0,
    eval_every: int = 1,
    verbose: bool = False,
    churn=None,
    n_groups: int = 3,
    staleness_lambda: float = 1.0,
    max_merges: int | None = None,
    checkpoint_path: str | None = None,
    checkpoint_every: int = 10,
    resume: dict | None = None,
) -> list[RoundLog]:
    """Async tier federation: ``n_rounds`` is a per-group wave budget, so the
    total merge budget is ``n_rounds * n_groups`` (comparable local work to
    ``n_rounds`` synchronous rounds when groups are balanced).

    Wave 0 is a synchronous profiling round over all participants — it seeds
    the speed estimates that ``async_groups`` needs, exactly like FedAT's
    tier-profiling phase. After it, each group schedules its own completion
    events and the clock advances per *group* straggler, never per global
    straggler. A wave trains from the global params as they were at wave
    LAUNCH (the model the tier downloaded), not from merges that landed
    while the wave was in flight — that staleness is the phenomenon the
    staleness-weighted merge compensates for.

    ``checkpoint_every`` counts merges (the async analogue of rounds).
    """
    if resume is not None:
        raise ValueError("resume is supported for engine='rounds'/'events' "
                         "only (the async engine's in-flight wave queue is "
                         "not checkpointed)")
    rng = _participants_rng()
    eval_fn, eval_batch = _eval_setup(trainer, eval_batch)
    q = EventQueue()
    logs: list[RoundLog] = []
    n_clients = len(trainer.clients)
    budget = max_merges if max_merges is not None else max(1, n_rounds) * n_groups

    # ---- wave 0: synchronous profiling round (seeds speed estimates) ----
    pool = churn.begin_round(0) if churn is not None else np.arange(n_clients)
    n_part = max(1, min(len(pool), int(participation * n_clients)))
    participants = sorted(rng.choice(pool, n_part, replace=False).tolist())
    plan0 = trainer.plan_round(0, participants)
    trainer.execute_round(0, plan0, plan0.trained)
    idx0 = list(range(len(plan0.trained)))
    trainer.observe_round(
        plan0, idx0,
        plan0.obs["t"] if plan0.obs is not None else plan0.times, plan0.times,
    )
    q.advance_to(float(plan0.times.max()))
    acc = float(eval_fn(trainer.params, eval_batch))
    logs.append(RoundLog(0, q.now, acc, plan0.assign, float(plan0.times.max()),
                         uplink_bytes=getattr(trainer, "last_uplink_bytes", 0.0),
                         hosts=_plan_hosts(plan0)))
    if target_acc is not None and acc >= target_acc:
        return logs

    # ---- async phase ----
    groups = trainer.async_groups(list(range(n_clients)), n_groups)
    tier_model: dict[int, object] = {}
    tier_weight: dict[int, float] = {}
    last_merge: dict[int, int] = {}
    wave_idx = {g: 1 for g in range(len(groups))}
    last_wave_time = {g: float(plan0.times.max()) for g in range(len(groups))}
    version = 0
    merges = 0

    def launch(g: int) -> None:
        members = groups[g]
        if churn is not None:
            act = set(churn.active())
            members = [k for k in members if k in act]
        if participation < 1.0 and members:
            m = max(1, int(participation * len(members)))
            members = sorted(rng.choice(members, m, replace=False).tolist())
        if not members:
            # whole group offline: re-poll after the group's last wave
            # duration (its natural pace), so rejoin latency stays bounded
            q.push_in(max(last_wave_time[g], 1.0), "wave", g=g, plan=None)
            return
        plan = trainer.plan_round(wave_idx[g], members)
        last_wave_time[g] = float(plan.times.max())
        # snapshot the global params the tier downloads at wave start; the
        # wave trains from this even if other groups merge meanwhile
        q.push_in(last_wave_time[g], "wave", g=g, plan=plan,
                  start_params=trainer.params)

    for g in range(len(groups)):
        launch(g)

    while merges < budget:
        ev = q.pop()
        if ev is None:
            break
        g, plan = ev.payload["g"], ev.payload["plan"]
        if churn is not None:
            churn.begin_round(wave_idx[g])
        if plan is None:
            launch(g)
            continue
        # churn inside the wave: dropouts leave the wave, switches re-roll
        # the ground-truth profile for FUTURE waves (the coarse per-group
        # event already fired, so no mid-wave reschedule is needed)
        idx = list(range(len(plan.trained)))
        if churn is not None:
            for kind, i, _ in churn.sample_mid_round(plan.trained, plan.times):
                if kind == "dropout":
                    churn.mark_offline(plan.trained[i])
                    idx.remove(i)
                else:
                    churn.resample_profile(trainer.env, plan.trained[i])
        trained = [plan.trained[i] for i in idx]
        wave_time = float(plan.times.max())
        if trained:
            # train from the wave-launch snapshot (the model the tier
            # actually downloaded), then restore the merged global
            current = trainer.params
            trainer.params = ev.payload["start_params"]
            try:
                tree, w = trainer.train_group(wave_idx[g], plan, trained)
            finally:
                trainer.params = current
            tier_model[g], tier_weight[g] = tree, w
            last_merge[g] = version
            version += 1
            # staleness-weighted cross-tier merge over groups that reported
            gs = sorted(tier_model)
            betas = [
                tier_weight[x] / (1.0 + staleness_lambda * (version - 1 - last_merge[x]))
                for x in gs
            ]
            trainer.params = aggregation.weighted_average(
                [tier_model[x] for x in gs], betas
            )
            obs_t = (plan.obs["t"][np.asarray(idx, int)]
                     if plan.obs is not None else plan.times[np.asarray(idx, int)])
            trainer.observe_round(plan, idx, obs_t, plan.times)
            merges += 1
            acc = float(eval_fn(trainer.params, eval_batch)) if (
                merges % eval_every == 0) else logs[-1].acc
            logs.append(RoundLog(merges, q.now, acc, dict(plan.assign), wave_time,
                                 uplink_bytes=getattr(trainer, "last_uplink_bytes", 0.0),
                                 hosts=_plan_hosts(plan)))
            if verbose:
                print(f"[async:{trainer.name}] merge={merges} group={g} "
                      f"clock={q.now:.0f}s acc={acc:.3f}")
            if checkpoint_path and merges % checkpoint_every == 0:
                save_train_state(checkpoint_path, trainer, round_=merges,
                                 clock=q.now, acc=acc, engine="async")
            if target_acc is not None and acc >= target_acc:
                break
        wave_idx[g] += 1
        launch(g)
    if checkpoint_path:
        save_train_state(checkpoint_path, trainer, round_=merges, clock=q.now,
                         acc=logs[-1].acc, engine="async")
    return logs
