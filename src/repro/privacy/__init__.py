"""Privacy add-ons (paper §4.4): distance correlation + patch shuffling.

* ``dcor(x, z)`` — (biased) sample distance correlation between raw inputs
  and the intermediate representation z, used as a regularizer
  ``(1-a)·task_loss + a·DCor(x, z)`` (Vepakomma et al. 2020 / NoPeek).
  The O(B^2·d) pairwise-distance hot spot has a Pallas kernel
  (kernels/dcor.py); this module is the pure-jnp reference used by default.

* ``patch_shuffle`` — permutes spatial patches / sequence chunks of the
  intermediate activations before upload (Yao et al. 2022).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pairwise_dist(x2d: jax.Array) -> jax.Array:
    """Euclidean distance matrix, (B, B) fp32."""
    x = x2d.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.sqrt(jnp.maximum(d2, 1e-12))


def _center(d: jax.Array) -> jax.Array:
    return d - d.mean(0, keepdims=True) - d.mean(1, keepdims=True) + d.mean()


def dcor(x: jax.Array, z: jax.Array) -> jax.Array:
    """Distance correlation in [0, 1]. Leading axis = batch; rest flattened."""
    B = x.shape[0]
    a = _center(_pairwise_dist(x.reshape(B, -1)))
    b = _center(_pairwise_dist(z.reshape(B, -1)))
    dcov2 = jnp.mean(a * b)
    dvar_x = jnp.mean(a * a)
    dvar_z = jnp.mean(b * b)
    return _safe_dcor_ratio(dcov2, dvar_x * dvar_z)


def _safe_dcor_ratio(dcov2: jax.Array, dvar_prod: jax.Array) -> jax.Array:
    """sqrt(dcov2 / sqrt(dvar_x * dvar_z)) with guards OUTSIDE the result:
    independent (dcov2 <= 0) or zero-variance inputs return exactly 0, and
    gradients stay finite (the old ``sqrt(ratio + 1e-12)`` floored every
    result at ~1e-6, biasing e.g. the Table-5 alpha sweep at dcor ~ 0)."""
    den = jnp.sqrt(jnp.maximum(dvar_prod, 0.0))
    ratio = jnp.where(den > 0.0, jnp.maximum(dcov2, 0.0) / jnp.maximum(den, 1e-30), 0.0)
    safe = ratio > 0.0
    # double-where keeps sqrt's gradient off the ratio<=0 branch (no NaNs)
    return jnp.where(safe, jnp.sqrt(jnp.where(safe, ratio, 1.0)), 0.0)


def patch_shuffle(key, z: jax.Array, n_patches: int = 16) -> jax.Array:
    """Shuffle contiguous chunks of z along the token/spatial axis (axis 1)."""
    B, S = z.shape[0], z.shape[1]
    p = n_patches
    while S % p:
        p -= 1
    perm = jax.random.permutation(key, p)
    zs = z.reshape(B, p, S // p, *z.shape[2:])
    return zs[:, perm].reshape(z.shape)
