"""The declarative experiment API: ``ExperimentSpec`` -> ``Federation``.

Every DTFL scenario this repo can express — tiers, schedulers, engines,
churn, codecs, exec planes, datasets, archs — is one frozen, JSON-
round-trippable :class:`ExperimentSpec`. The spec tree is validated at
construction against the component registries (``repro.registry``): an
invalid name or an illegal combination (``fedgkt`` + a lossy codec, churn on
the scalar-clock engine, resume into the async engine, ...) raises
:class:`SpecError` **before any jax import**, with the full legal choice set
in the message.

``spec.build()`` returns a :class:`Federation` facade that owns adapter /
clients / env / trainer construction and exposes ``run()`` / ``save()`` /
``resume()``. Every entry point — ``launch/train.py`` (flags -> spec),
``benchmarks/*`` (``repro.presets`` scenario library), ``benchmarks/
sweep.py`` (spec grids), the examples — converges on this one path, so the
wiring cannot drift per caller. The spec also stamps every training
checkpoint envelope (hash + canonical JSON), so ``resume()`` can verify it
is continuing the *same* experiment.

Construction is bit-compatible with the hand-rolled wiring it replaced:
``tests/test_api.py`` pins that the same ``train.py`` flag vector produces
bit-identical ``RoundLog`` streams through this path as commit f781a4b's
direct wiring.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field, fields

from repro import registry
from repro.registry import RegistryError


class SpecError(ValueError):
    """Invalid ExperimentSpec (bad name, bad value, or illegal combo)."""


def _positive(name: str, v, *, minimum=1) -> None:
    if v < minimum:
        raise SpecError(f"{name} must be >= {minimum}, got {v!r}")


def _validated(reg, name: str):
    try:
        return reg.validate(name)
    except RegistryError as e:
        raise SpecError(str(e)) from None


# ---------------------------------------------------------------------------
# the spec tree
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelSpec:
    """``arch`` picks the model family + adapter (registry ``archs``).
    ``full_size=False`` trains the ``reduced()`` CPU variant. ``cost_model``
    prices the analytic time model: None = the arch's FULL config (the
    paper's regime), ``"self"`` = the trained config itself, or a registered
    resnet name (Table 3 prices the reduced model on full ResNet-110)."""

    arch: str = "resnet-56"
    full_size: bool = False
    cost_model: str | None = None

    def __post_init__(self):
        _validated(registry.archs, self.arch)
        if self.cost_model not in (None, "self"):
            kind = registry.archs.meta(self.cost_model).get("kind") \
                if self.cost_model in registry.archs else None
            if kind != "resnet":
                raise SpecError(
                    f"cost_model {self.cost_model!r} must be None, 'self', or "
                    f"a registered resnet arch: "
                    + ", ".join(n for n in registry.archs.names()
                                if registry.archs.meta(n)["kind"] == "resnet"))

    @property
    def kind(self) -> str:
        return registry.archs.meta(self.arch)["kind"]


@dataclass(frozen=True)
class DataSpec:
    """Client data plane. Image datasets follow the ``train.py`` protocol
    (labels from ``default_rng(seed)``, iid or Dirichlet(alpha) partition);
    ``dataset="lm"`` is the token-LM task (``n_batches`` batches/client).
    ``eval_size=None`` resolves to 512 images / one ``batch_size`` LM batch."""

    dataset: str = "cifar10"
    clients: int = 10
    samples: int = 2000
    batch_size: int = 32
    iid: bool = False
    alpha: float = 0.5
    seq_len: int = 128
    n_batches: int = 2
    eval_size: int | None = None
    # population plane: a lazy registry of this many clients replaces the
    # dense ``clients`` list; per-client data/profile state is derived from
    # (seed, cid) on FIRST participation, so 10^5-10^6 registries cost
    # O(sampled). In population mode ``samples`` counts samples PER CLIENT
    # (a dense-mode global pool would itself be O(population)).
    population: int | None = None

    def __post_init__(self):
        _validated(registry.datasets, self.dataset)
        _positive("data.clients", self.clients)
        _positive("data.samples", self.samples)
        _positive("data.batch_size", self.batch_size)
        _positive("data.seq_len", self.seq_len)
        _positive("data.n_batches", self.n_batches)
        if self.eval_size is not None:
            _positive("data.eval_size", self.eval_size)
        if self.population is not None:
            _positive("data.population", self.population)

    @property
    def n_clients(self) -> int:
        """Registered clients: the lazy registry size in population mode,
        the dense ``clients`` count otherwise."""
        return self.clients if self.population is None else self.population

    @property
    def kind(self) -> str:
        return registry.datasets.meta(self.dataset)["kind"]


@dataclass(frozen=True)
class EnvSpec:
    """Heterogeneous resource environment: a registered profile-pool name
    (``paper``/``case1``/``case2``/``slow10mbps``) or an explicit tuple of
    ``(cpu_share, mbps)`` pairs; profiles of 30% of clients re-roll every
    ``switch_every`` rounds (0 disables switching)."""

    profiles: str | tuple = "paper"
    switch_every: int = 50

    def __post_init__(self):
        if isinstance(self.profiles, str):
            _validated(registry.profile_pools, self.profiles)
        else:
            try:
                pool = tuple(
                    (float(f), float(b)) for f, b in self.profiles)
            except (TypeError, ValueError):
                raise SpecError(
                    f"env.profiles must be a registered pool name "
                    f"({', '.join(registry.profile_pools.names())}) or a "
                    f"list of (cpu_share, mbps) pairs, got {self.profiles!r}"
                ) from None
            if not pool:
                raise SpecError("env.profiles custom pool is empty")
            object.__setattr__(self, "profiles", pool)
        _positive("env.switch_every", self.switch_every, minimum=0)


@dataclass(frozen=True)
class TrainerSpec:
    """Algorithm + its local-training knobs. ``scheduler`` is DTFL's tier
    scheduler spec (``dynamic`` | ``dynamic:<M>`` | a fixed tier index |
    ``pairing[:greedy]``) and is rejected for methods that have no tier
    scheduler. ``topology`` picks the offload topology (``server`` |
    ``pairing``, core/topology.py); ``scheduler=pairing`` and
    ``topology=pairing`` imply each other and are kept coherent here.
    ``options`` passes extra registered-trainer constructor kwargs (e.g.
    fedyogi's ``server_lr``) — keys must be identifiers."""

    method: str = "dtfl"
    scheduler: str | int = "dynamic"
    topology: str = "server"
    lr: float = 1e-3
    local_epochs: int = 1
    dcor_alpha: float = 0.0
    patch_shuffle: bool = False
    # absolute participants per round (population plane: "sample 512 of the
    # 10^6 registry"); None keeps fractional ``participation`` sizing
    sample_size: int | None = None
    options: dict = field(default_factory=dict)

    def __post_init__(self):
        _validated(registry.trainers, self.method)
        if self.sample_size is not None:
            _positive("trainer.sample_size", self.sample_size)
        canon = _validated(registry.schedulers, self.scheduler)
        object.__setattr__(
            self, "scheduler",
            int(canon) if canon.lstrip("-").isdigit() else canon)
        topo = _validated(registry.topologies, self.topology)
        pairing_sched = (isinstance(self.scheduler, str)
                         and self.scheduler.startswith("pairing"))
        if topo == "pairing" and not pairing_sched:
            if self.scheduler != "dynamic":
                raise SpecError(
                    f"trainer.topology='pairing' requires "
                    f"trainer.scheduler='pairing' (or 'pairing:greedy'), got "
                    f"scheduler={self.scheduler!r}")
            object.__setattr__(self, "scheduler", "pairing")
        elif pairing_sched:
            topo = "pairing"
        object.__setattr__(self, "topology", topo)
        _positive("trainer.lr", self.lr, minimum=0)
        _positive("trainer.local_epochs", self.local_epochs)
        if not isinstance(self.options, dict) or not all(
                isinstance(k, str) and k.isidentifier() for k in self.options):
            raise SpecError(
                f"trainer.options must map identifier kwargs to values, got "
                f"{self.options!r}")


@dataclass(frozen=True)
class ChurnSpec:
    """Client churn (events/async engines only): mid-round dropout /
    profile-switch probabilities, initially-offline fraction, rejoin delay.
    ``seed=None`` uses the experiment seed."""

    drop: float = 0.1
    switch: float = 0.1
    offline_frac: float = 0.0
    rejoin: int = 2
    seed: int | None = None

    def __post_init__(self):
        for n in ("drop", "switch", "offline_frac"):
            v = getattr(self, n)
            if not 0.0 <= v <= 1.0:
                raise SpecError(f"engine.churn.{n} must be in [0, 1], got {v!r}")
        _positive("engine.churn.rejoin", self.rejoin)


@dataclass(frozen=True)
class EngineSpec:
    """Round engine: ``auto`` resolves to ``async`` for fedat, ``rounds``
    otherwise (exactly ``train.py``'s default). ``n_groups`` is the async
    speed-group count."""

    name: str = "auto"
    n_groups: int = 3
    churn: ChurnSpec | None = None

    def __post_init__(self):
        if self.name != "auto":
            _validated(registry.engines, self.name)
        _positive("engine.n_groups", self.n_groups)


@dataclass(frozen=True)
class ExecSpec:
    """Execution plane: ``loop`` | ``cohort`` | ``sharded`` (+ mesh size) |
    ``chunked`` (+ ``chunk_size`` clients per device program — memory stays
    O(chunk), bit-equal to ``cohort``)."""

    mode: str = "cohort"
    devices: int | None = None
    chunk_size: int | None = None

    def __post_init__(self):
        _validated(registry.exec_modes, self.mode)
        if self.devices is not None:
            _positive("exec.devices", self.devices)
        if self.chunk_size is not None:
            _positive("exec.chunk_size", self.chunk_size)
            if self.mode != "chunked":
                raise SpecError(
                    f"exec.chunk_size applies to exec.mode='chunked' only; "
                    f"got mode={self.mode!r}")


@dataclass(frozen=True)
class CodecSpec:
    """Wire codec for the three wires (z uplink, model download, update
    upload): any spec registered with ``register_codec``."""

    name: str = "identity"

    def __post_init__(self):
        object.__setattr__(
            self, "name",
            _validated(registry.codecs, str(self.name).strip().lower()))

    @property
    def is_identity(self) -> bool:
        return bool(registry.codecs.meta(self.name).get("identity"))


@dataclass(frozen=True)
class CheckpointSpec:
    """Resumable-train-state envelope: write to ``path`` every ``every``
    rounds; ``resume`` restores (and spec-hash-verifies) an envelope."""

    path: str | None = None
    every: int = 10
    resume: str | None = None

    def __post_init__(self):
        _positive("checkpoint.every", self.every)


_NESTED = {"model": ModelSpec, "data": DataSpec, "env": EnvSpec,
           "trainer": TrainerSpec, "engine": EngineSpec, "exec": ExecSpec,
           "codec": CodecSpec, "checkpoint": CheckpointSpec}
# spec groups with_overrides may auto-create from None (nested optionals
# like engine.churn included)
_AUTO_GROUPS = frozenset(_NESTED) | {"churn"}
# run-length / IO knobs excluded from the experiment identity hash, so a
# checkpointed run can legally be resumed with a larger --rounds budget
_NON_IDENTITY_FIELDS = ("rounds", "target_acc", "checkpoint")


@dataclass(frozen=True)
class ExperimentSpec:
    """The root spec. Frozen, JSON-round-trippable, registry-validated."""

    model: ModelSpec = field(default_factory=ModelSpec)
    data: DataSpec = field(default_factory=DataSpec)
    env: EnvSpec = field(default_factory=EnvSpec)
    trainer: TrainerSpec = field(default_factory=TrainerSpec)
    engine: EngineSpec = field(default_factory=EngineSpec)
    exec: ExecSpec = field(default_factory=ExecSpec)
    codec: CodecSpec = field(default_factory=CodecSpec)
    checkpoint: CheckpointSpec = field(default_factory=CheckpointSpec)
    rounds: int = 20
    target_acc: float | None = None
    participation: float = 1.0
    eval_every: int = 1
    seed: int = 0

    # ------------------------------------------------------------------
    def __post_init__(self):
        _positive("rounds", self.rounds)
        _positive("eval_every", self.eval_every)
        if not 0.0 < self.participation <= 1.0:
            raise SpecError(
                f"participation must be in (0, 1], got {self.participation!r}")

        meta = registry.trainers.meta(self.trainer.method)
        # arch kind <-> data kind
        want = "lm" if self.model.kind == "transformer" else "image"
        if self.data.kind != want:
            names = [n for n in registry.datasets.names()
                     if registry.datasets.meta(n)["kind"] == want]
            raise SpecError(
                f"arch {self.model.arch!r} ({self.model.kind}) needs a "
                f"{want} dataset; got {self.data.dataset!r} "
                f"({self.data.kind}). Legal: {', '.join(names)}")
        if self.trainer.patch_shuffle and self.model.kind != "resnet":
            raise SpecError("trainer.patch_shuffle is an image-adapter knob; "
                            "it is not supported for transformer archs")
        # scheduler is a tier-scheduling knob; only scheduler-aware trainers
        # (dtfl) accept one
        if self.trainer.scheduler != "dynamic" and not meta.get("scheduler_aware"):
            aware = [n for n in registry.trainers.names()
                     if registry.trainers.meta(n).get("scheduler_aware")]
            raise SpecError(
                f"trainer.scheduler={self.trainer.scheduler!r} requires a "
                f"tier-scheduling method ({', '.join(aware)}); "
                f"{self.trainer.method!r} has no tier scheduler")
        # codec plane contract
        if not self.codec.is_identity and not meta.get("supports_codec", True):
            ok = [n for n in registry.trainers.names()
                  if registry.trainers.meta(n).get("supports_codec", True)]
            raise SpecError(
                f"method {self.trainer.method!r} does not support wire "
                f"compression (codec={self.codec.name!r}); its round "
                f"structure is not the download/update-upload contract the "
                f"codec plane compresses. Codec-capable methods: "
                + ", ".join(ok))
        # engine combos
        engine = self.resolved_engine
        if engine == "async" and not meta.get("supports_async", True):
            ok = [n for n in registry.trainers.names()
                  if registry.trainers.meta(n).get("supports_async", True)]
            raise SpecError(
                f"method {self.trainer.method!r} has no faithful async "
                f"formulation; engine='async' supports: {', '.join(ok)} "
                f"(use engine='rounds' or 'events')")
        if self.engine.churn is not None and engine == "rounds":
            raise SpecError(
                "engine.churn requires the event-driven engines "
                "(engine='events' or 'async'); the scalar-clock 'rounds' "
                "loop cannot express mid-round churn")
        # population plane combos (lazy registry + fixed-size sampling)
        if self.data.population is not None and engine == "async":
            raise SpecError(
                "data.population (the lazy client registry) supports "
                "engine='rounds'|'events' only; the async engine speed-"
                "groups the FULL population, which defeats lazy state")
        if self.trainer.sample_size is not None and engine == "async":
            raise SpecError(
                "trainer.sample_size is a rounds/events sampling knob; the "
                "async engine groups the full population (use "
                "participation)")
        if self.checkpoint.resume:
            if engine == "async":
                raise SpecError(
                    "checkpoint.resume supports engine='rounds'|'events' "
                    "only (the async engine's in-flight wave queue is not "
                    "checkpointed)")
            if self.engine.churn is not None:
                raise SpecError(
                    "checkpoint.resume with engine.churn is unsupported "
                    "(churn offline/arrival state is not checkpointed)")

    # ------------------------------------------------------------------
    @property
    def resolved_engine(self) -> str:
        if self.engine.name != "auto":
            return self.engine.name
        return "async" if self.trainer.method == "fedat" else "rounds"

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, *, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent,
                          default=_json_default)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        return _build_spec(cls, d, "spec")

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def with_overrides(self, overrides: dict) -> "ExperimentSpec":
        """New spec with dotted-path fields replaced (``{"trainer.method":
        "fedavg", "rounds": 2}``); string values are JSON-parsed when
        possible. Revalidates the full tree."""
        d = self.to_dict()
        for path, value in overrides.items():
            node, parts = d, path.split(".")
            for p in parts[:-1]:
                if not isinstance(node.get(p), dict):
                    if p in _AUTO_GROUPS and node.get(p) is None:
                        node[p] = {}  # e.g. engine.churn.drop on churn=None
                    else:
                        raise SpecError(f"override path {path!r}: no spec "
                                        f"group {p!r}")
                node = node[p]
            if isinstance(value, str):
                try:
                    value = json.loads(value)
                except (ValueError, TypeError):
                    pass
            node[parts[-1]] = value
        return type(self).from_dict(d)

    # ------------------------------------------------------------------
    # identity
    # ------------------------------------------------------------------
    def identity_dict(self) -> dict:
        """The experiment-identity fields: everything except run-length and
        checkpoint-IO knobs (so resuming with a larger round budget is the
        same experiment)."""
        d = self.to_dict()
        for k in _NON_IDENTITY_FIELDS:
            d.pop(k, None)
        return d

    def spec_hash(self) -> str:
        blob = json.dumps(self.identity_dict(), sort_keys=True,
                          default=_json_default)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def program_key(self) -> tuple:
        """Everything the jitted per-tier programs close over. Two specs
        with equal keys can share one Federation's compiled programs
        (``Federation(spec, reuse=prev)``) — the sweep plane's speed win on
        recompilation-dominated grids."""
        t, m, d = self.trainer, self.model, self.data
        return (t.method, m.arch, m.full_size, d.dataset, d.batch_size,
                d.seq_len, d.n_batches, t.lr, t.local_epochs, t.dcor_alpha,
                t.patch_shuffle, tuple(sorted(t.options.items())),
                self.codec.name, self.exec.mode, self.exec.devices,
                self.exec.chunk_size)

    # ------------------------------------------------------------------
    def build(self, *, reuse: "Federation | None" = None) -> "Federation":
        return Federation(self, reuse=reuse)


def _json_default(o):
    raise TypeError(f"spec field value {o!r} is not JSON-serializable")


def _build_spec(cls, d: dict, path: str):
    if not isinstance(d, dict):
        raise SpecError(f"{path} must be a JSON object, got {d!r}")
    known = {f.name for f in fields(cls)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise SpecError(
            f"unknown field(s) {', '.join(f'{path}.{u}' for u in unknown)}; "
            f"known fields: {', '.join(sorted(known))}")
    kw = {}
    for k, v in d.items():
        sub = _NESTED.get(k) if cls is ExperimentSpec else (
            ChurnSpec if (cls is EngineSpec and k == "churn") else None)
        if sub is not None and v is not None:
            v = _build_spec(sub, v, f"{path}.{k}")
        kw[k] = v
    try:
        return cls(**kw)
    except TypeError as e:
        raise SpecError(f"{path}: {e}") from None


# ---------------------------------------------------------------------------
# the Federation facade
# ---------------------------------------------------------------------------

# compiled-program attributes transplanted between trainers whose specs share
# a program_key: the per-tier jitted cohort/sharded/step programs (DTFL), the
# full-model programs (baselines), and the jitted eval (fed/engine.py reuses
# a trainer-cached _eval_jit)
_PROGRAM_ATTRS = ("_step_cache", "_cohort_cache", "_sharded_cache",
                  "_full_step", "_full_cohort_program", "_full_sharded",
                  "_eval_jit")


class Federation:
    """Owns the built experiment: adapter, clients, env, trainer, eval batch.

    ``run()`` executes ``spec.rounds`` rounds on the spec's engine and
    returns the ``RoundLog`` list; ``save(path)`` dumps the trainer state;
    ``resume(path)`` loads a checkpoint envelope and verifies its spec stamp
    before the next ``run()`` continues it.

    ``reuse=`` transplants the compiled per-tier programs (and jitted eval)
    of a previous Federation whose spec shares this spec's
    ``program_key()`` — on CPU-bound sweep grids, recompilation dominates
    small runs, so this is the sweep plane's main speed lever.
    """

    def __init__(self, spec: ExperimentSpec, *, reuse: "Federation | None" = None):
        self.spec = spec
        self.logs = None
        self._resume = None

        if spec.exec.mode == "sharded" and spec.exec.devices:
            from repro.launch.mesh import ensure_sim_devices

            ensure_sim_devices(spec.exec.devices)

        from repro import optim
        from repro.fed import ExecPlan, HeteroEnv

        cfg_full = registry.archs.build(spec.model.arch)
        cfg = cfg_full if spec.model.full_size else cfg_full.reduced()
        self.cfg = cfg
        if spec.model.kind == "resnet":
            from repro.configs.resnet_cifar import get_resnet
            from repro.fed import ResNetAdapter

            if spec.model.cost_model == "self":
                cost_cfg = None
            elif spec.model.cost_model is None:
                cost_cfg = cfg_full
            else:
                cost_cfg = get_resnet(spec.model.cost_model)
            self.adapter = ResNetAdapter(
                cfg, cost_cfg=cost_cfg, dcor_alpha=spec.trainer.dcor_alpha,
                patch_shuffle=spec.trainer.patch_shuffle)
            self.clients, self.eval_batch = _build_image_data(spec, cfg)
        else:
            from repro.fed import TransformerAdapter

            cost_cfg = None if spec.model.cost_model == "self" else cfg_full
            self.adapter = TransformerAdapter(
                cfg, seq_len=spec.data.seq_len, cost_cfg=cost_cfg,
                dcor_alpha=spec.trainer.dcor_alpha)
            self.clients, self.eval_batch = _build_lm_data(spec, cfg)

        profiles = spec.env.profiles
        if isinstance(profiles, str):
            # the default pool passes None so HeteroEnv keeps its legacy
            # (bit-identical) construction; named pools resolve here
            profiles = (None if profiles == "paper"
                        else registry.profile_pools.build(profiles))
        else:
            from repro.core.timemodel import ResourceProfile

            profiles = [ResourceProfile(f, b) for f, b in profiles]
        if spec.data.population is not None:
            # population plane: O(1)-construction env; profiles draw from
            # (seed, cid) on first touch instead of a dense assignment array
            from repro.fed import LazyHeteroEnv

            self.env = LazyHeteroEnv(spec.data.n_clients, profiles=profiles,
                                     switch_every=spec.env.switch_every,
                                     seed=spec.seed)
        else:
            self.env = HeteroEnv(spec.data.clients, profiles=profiles,
                                 switch_every=spec.env.switch_every,
                                 seed=spec.seed)

        cls = registry.trainers.load(spec.trainer.method)
        kw = dict(spec.trainer.options)
        if registry.trainers.meta(spec.trainer.method).get("scheduler_aware"):
            kw["scheduler"] = spec.trainer.scheduler
            kw["topology"] = spec.trainer.topology
        kw["exec_plan"] = ExecPlan.from_flags(spec.exec.mode,
                                              devices=spec.exec.devices,
                                              chunk_size=spec.exec.chunk_size)
        kw["codec"] = spec.codec.name
        self.trainer = cls(self.adapter, self.clients, self.env,
                           optim.adam(spec.trainer.lr), seed=spec.seed,
                           local_epochs=spec.trainer.local_epochs, **kw)
        # the engine stamps every checkpoint envelope with this, so resume
        # can verify it is continuing the SAME experiment
        self.trainer._spec_stamp = {"hash": spec.spec_hash(),
                                    "json": spec.to_json()}

        self.programs_reused = False
        if reuse is not None and reuse.spec.program_key() == spec.program_key():
            self._adopt_programs(reuse)

    # ------------------------------------------------------------------
    def _adopt_programs(self, other: "Federation") -> None:
        src, dst = other.trainer, self.trainer
        if type(src) is not type(dst):
            return
        for a in _PROGRAM_ATTRS:
            if hasattr(src, a):
                v = getattr(src, a)
                setattr(dst, a, dict(v) if isinstance(v, dict) else v)
        self.programs_reused = True

    # ------------------------------------------------------------------
    def run(self, *, verbose: bool = False):
        sp = self.spec
        engine = sp.resolved_engine
        churn = None
        if sp.engine.churn is not None:
            from repro.fed import ChurnModel

            c = sp.engine.churn
            churn = ChurnModel(
                sp.data.n_clients, drop_prob=c.drop, switch_prob=c.switch,
                start_offline_frac=c.offline_frac, rejoin_after=c.rejoin,
                seed=sp.seed if c.seed is None else c.seed)
        run_kw = {"engine": engine}
        if engine == "async":
            run_kw["n_groups"] = sp.engine.n_groups
        if sp.trainer.sample_size is not None:
            run_kw["sample_size"] = sp.trainer.sample_size
        if sp.checkpoint.path:
            run_kw["checkpoint_path"] = sp.checkpoint.path
            run_kw["checkpoint_every"] = sp.checkpoint.every
        resume = self._resume
        if resume is None and sp.checkpoint.resume:
            resume = self._load_verified(sp.checkpoint.resume)
        if resume is not None:
            run_kw["resume"] = resume
            self._resume = None
            if verbose:
                print(f"[api] resuming at round {int(resume['round'])} "
                      f"(spec {self.spec.spec_hash()})")
        self.logs = self.trainer.run(
            sp.rounds, self.eval_batch, target_acc=sp.target_acc,
            participation=sp.participation, eval_every=sp.eval_every,
            verbose=verbose, churn=churn, **run_kw)
        return self.logs

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        """Dump the trainer's state (weights-level; for the full resumable
        envelope use ``spec.checkpoint.path`` so the engine writes round /
        clock / rng cursors too)."""
        self.trainer.save(path)

    def resume(self, path: str) -> "Federation":
        """Load + spec-verify a train-state envelope; the next ``run()``
        continues it."""
        self._resume = self._load_verified(path)
        return self

    def _load_verified(self, path: str) -> dict:
        from repro import checkpoint as ckpt

        envelope = ckpt.load(path)
        stamp = envelope.get("spec") if isinstance(envelope, dict) else None
        if stamp is not None:
            have = str(stamp["hash"])
            want = self.spec.spec_hash()
            if have != want:
                raise SpecError(
                    f"checkpoint {path!r} was written by a different "
                    f"experiment (spec hash {have} != {want}). The stored "
                    f"spec was:\n{str(stamp['json'])}\nDiffering fields "
                    "must match for a resume to be meaningful (rounds / "
                    "target_acc / checkpoint paths are exempt).")
        return envelope


# ---------------------------------------------------------------------------
# data builders (the exact ``launch/train.py`` construction, shared by every
# entry point so streams stay bit-identical)
# ---------------------------------------------------------------------------

def _build_image_data(spec: ExperimentSpec, cfg):
    import numpy as np

    from repro.data.partition import dirichlet_partition, iid_partition
    from repro.data.pipeline import ClientDataset, make_eval_batch
    from repro.data.synthetic import ClassImageTask
    from repro.fed import SimClient

    ds = registry.datasets.meta(spec.data.dataset)
    task = ClassImageTask(n_classes=ds["n_classes"], image_size=cfg.image_size,
                          noise=ds["noise"], seed=ds["seed"])
    if spec.data.population is not None:
        # population plane: each client's labels are a pure function of
        # (seed, cid) — iid uniform, or a per-client Dirichlet(alpha) class
        # mix — built on FIRST participation by the lazy store's factory, so
        # a 10^6-client registry allocates nothing up front. ``samples`` is
        # per client here (a global label pool would itself be O(population)).
        from repro.fed import ClientStore
        from repro.fed.population import cid_rng

        per, bs, n_cls = spec.data.samples, spec.data.batch_size, task.n_classes
        iid, alpha, seed = spec.data.iid, spec.data.alpha, spec.seed

        def factory(cid: int):
            r = cid_rng(seed, 21, cid)
            if iid:
                labels = r.integers(0, n_cls, per)
            else:
                labels = r.choice(n_cls, size=per, p=r.dirichlet([alpha] * n_cls))
            # seed=cid+1: distinct per-client batch-shuffle streams (0 is
            # the dense path's shared legacy stream)
            return SimClient(
                cid, ClientDataset(task, labels, np.arange(per), bs, seed=cid + 1),
                None)

        return (ClientStore(spec.data.population, factory),
                make_eval_batch(task, spec.data.eval_size or 512))
    rng = np.random.default_rng(spec.seed)
    labels = rng.integers(0, task.n_classes, spec.data.samples)
    if spec.data.iid:
        parts = iid_partition(labels, spec.data.clients, seed=spec.seed)
    else:
        parts = dirichlet_partition(labels, spec.data.clients,
                                    spec.data.alpha, seed=spec.seed)
    clients = [
        SimClient(i, ClientDataset(task, labels, parts[i], spec.data.batch_size),
                  None)
        for i in range(spec.data.clients)
    ]
    return clients, make_eval_batch(task, spec.data.eval_size or 512)


def _build_lm_data(spec: ExperimentSpec, cfg):
    from repro.data.pipeline import SeqClientDataset
    from repro.data.synthetic import SeqTask
    from repro.fed import SimClient

    task = SeqTask(vocab=cfg.vocab)
    if spec.data.population is not None:
        from repro.fed import ClientStore

        d = spec.data
        clients = ClientStore(
            d.population,
            lambda cid: SimClient(
                cid, SeqClientDataset(task, d.n_batches, d.batch_size,
                                      d.seq_len, cid), None))
    else:
        clients = [
            SimClient(i, SeqClientDataset(task, spec.data.n_batches,
                                          spec.data.batch_size,
                                          spec.data.seq_len, i), None)
            for i in range(spec.data.clients)
        ]
    ev = next(task.batches(spec.data.eval_size or spec.data.batch_size,
                           spec.data.seq_len, 1, seed=99))
    return clients, ev


def __getattr__(name: str):
    if name == "presets":  # lazy: repro.presets imports this module
        import repro.presets as presets

        return presets
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
