"""Jit'd wrappers for the Pallas kernels with interpret/TPU dispatch.

On this CPU container kernels always run in interpret mode (the Python body
executes per grid cell); on TPU backends the same ``pl.pallas_call`` lowers
to Mosaic. ``ON_TPU`` picks the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dcor import dcor_kernelized, pairwise_dist
from repro.kernels.fused_xent import fused_xent
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels.quantize import int8_roundtrip

ON_TPU = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0):
    """(B, S, H, hd) layout wrapper: folds heads into the grid batch."""
    B, S, H, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention(
        fold(q), fold(k), fold(v), causal=causal, window=window,
        interpret=not ON_TPU,
    )
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@jax.jit
def mlstm_chunk_op(q, k, v, log_f, i_gate):
    """(B, H, S, dh) layout wrapper."""
    B, H, S, dh = q.shape
    fold3 = lambda t: t.reshape(B * H, S, dh)
    fold2 = lambda t: t.reshape(B * H, S)
    out = mlstm_chunk(
        fold3(q), fold3(k), fold3(v), fold2(log_f), fold2(i_gate),
        interpret=not ON_TPU,
    )
    return out.reshape(B, H, S, dh)


@jax.jit
def pairwise_dist_op(x):
    return pairwise_dist(x, interpret=not ON_TPU)


@jax.jit
def dcor_op(x, z):
    return dcor_kernelized(x, z, interpret=not ON_TPU)


@jax.jit
def int8_roundtrip_op(x):
    """Fused per-tensor-scale int8 quantize/dequantize — the communication
    plane's int8 wire transform (kernels/quantize.py)."""
    return int8_roundtrip(x, interpret=not ON_TPU)


@jax.jit
def fused_xent_op(logits, labels):
    """Mean token cross-entropy over (..., V) logits without materializing
    a vocab-sized softmax (kernels/fused_xent.py)."""
    V = logits.shape[-1]
    flat = logits.reshape(-1, V)
    lab = labels.reshape(-1)
    per_tok = fused_xent(flat, lab, interpret=not ON_TPU)
    return per_tok.mean()
