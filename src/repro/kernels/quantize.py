"""Fused int8 per-tensor-scale quantize/dequantize Pallas kernel — the
communication plane's wire transform (core/codec.py: Int8Codec).

A real deployment quantizes on the sender and dequantizes on the receiver;
in simulation both ends live in one device program, so the kernel fuses the
pair into a single tiled pass (no int8 intermediate is ever materialized in
HBM — the round-trip is one read + one write per element). The per-tensor
scale ``s = max|x| / 127`` is a cheap O(n) jnp reduction outside the grid,
exactly like dcor's centering (kernels/dcor.py) stays in jnp.

Grid = (n/block,); each program quantizes one flat block:
``out = clip(round(x / s), -127, 127) * s``.

The pure-jnp oracle is ``kernels/ref.py: int8_roundtrip_ref`` (same op
order, so CPU interpret mode is bit-equal); ``kernels/ops.py:
int8_roundtrip_op`` is the jitted dispatch wrapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qdq_kernel(x_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[0, 0]
    q = jnp.clip(jnp.round(x / s), -127.0, 127.0)
    o_ref[...] = q * s


def int8_roundtrip(x: jax.Array, *, block: int = 4096, interpret: bool = True) -> jax.Array:
    """Quantize ``x`` to int8 with one per-tensor scale and dequantize back;
    any shape/float dtype, output dtype preserved."""
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    scale = (jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 127.0).reshape(1, 1)
    bb = min(block, n)
    pad = (-n) % bb
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    rows = flat.size // bb
    out = pl.pallas_call(
        _qdq_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, bb), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, bb), jnp.float32),
        interpret=interpret,
    )(flat.reshape(rows, bb), scale)
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)
