"""Pairwise Euclidean distance Pallas kernel — the O(B^2 * F) hot spot of the
distance-correlation privacy regularizer (paper §4.4, Vepakomma et al. 2020).

Grid = (B/bb, B/bb); each program computes one (bb, bb) distance tile from
two row blocks via ||x||^2 + ||y||^2 - 2 x y^T (one MXU matmul per tile).
Double-centering + the correlation ratio stay in jnp (O(B^2), cheap).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(xi_ref, xj_ref, o_ref):
    xi = xi_ref[...].astype(jnp.float32)      # (bb, F)
    xj = xj_ref[...].astype(jnp.float32)
    sq_i = jnp.sum(xi * xi, axis=1, keepdims=True)
    sq_j = jnp.sum(xj * xj, axis=1, keepdims=True)
    cross = jax.lax.dot_general(xi, xj, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = sq_i + sq_j.T - 2.0 * cross
    o_ref[...] = jnp.sqrt(jnp.maximum(d2, 1e-12))


def pairwise_dist(x: jax.Array, *, block: int = 128, interpret: bool = True) -> jax.Array:
    """x: (B, F) -> (B, B) Euclidean distances."""
    B, F = x.shape
    bb = min(block, B)
    while B % bb:
        bb -= 1
    grid = (B // bb, B // bb)
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, F), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, F), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, B), jnp.float32),
        interpret=interpret,
    )(x, x)


def dcor_kernelized(x: jax.Array, z: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Distance correlation using the Pallas distance tiles."""
    B = x.shape[0]
    a = pairwise_dist(x.reshape(B, -1), interpret=interpret)
    b = pairwise_dist(z.reshape(B, -1), interpret=interpret)

    def center(d):
        return d - d.mean(0, keepdims=True) - d.mean(1, keepdims=True) + d.mean()

    a, b = center(a), center(b)
    from repro.privacy import _safe_dcor_ratio

    return _safe_dcor_ratio(jnp.mean(a * b), jnp.mean(a * a) * jnp.mean(b * b))
