"""Fused cross-entropy Pallas kernel.

For vocab-heavy models (granite 49k, deepseek 102k, llama4 202k vocab) the
token cross-entropy is a real memory hot spot: the naive path materializes
fp32 log-softmax over (B, S, V). This kernel streams the vocab dimension in
VMEM-sized blocks computing an online logsumexp and picking the label logit
on the fly — the (B*S, V) logits are read once, nothing vocab-sized is ever
written.

grid = (n_token_blocks, n_vocab_blocks); the vocab axis is the sequential
TPU grid axis, so (m, l, picked) running stats live in VMEM scratch.
Outputs per-token loss (BT,); the mean reduction stays in jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _xent_kernel(logits_ref, labels_ref, loss_ref, m_ref, l_ref, pick_ref, *,
                 bt: int, bv: int):
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        pick_ref[...] = jnp.zeros_like(pick_ref)

    x = logits_ref[...].astype(jnp.float32)        # (bt, bv)
    labels = labels_ref[...]                       # (bt,)

    # online logsumexp over the vocab blocks
    m_prev = m_ref[...]                            # (bt, 1)
    m_cur = jnp.max(x, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(x - m_new), axis=1, keepdims=True
    )
    m_ref[...] = m_new

    # pick the label logit if it falls in this vocab block
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    hit = col == labels[:, None]
    pick_ref[...] = pick_ref[...] + jnp.sum(
        jnp.where(hit, x, 0.0), axis=1, keepdims=True
    )

    @pl.when(j == nv - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        loss_ref[...] = (lse - pick_ref[...])[:, 0].astype(loss_ref.dtype)


def fused_xent(logits: jax.Array, labels: jax.Array, *,
               block_tokens: int = 256, block_vocab: int = 2048,
               interpret: bool = True) -> jax.Array:
    """logits: (T, V); labels: (T,) int32. Returns per-token loss (T,) fp32.

    T must divide by block_tokens and V by block_vocab (callers pad; the
    ops.py wrapper handles ragged shapes).
    """
    T, V = logits.shape
    bt = min(block_tokens, T)
    while T % bt:
        bt -= 1
    bv = min(block_vocab, V)
    while V % bv:
        bv -= 1
    grid = (T // bt, V // bv)
    kernel = functools.partial(_xent_kernel, bt=bt, bv=bv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((T,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),   # running max
            pltpu.VMEM((bt, 1), jnp.float32),   # running sum
            pltpu.VMEM((bt, 1), jnp.float32),   # picked label logit
        ],
        interpret=interpret,
    )(logits, labels)
