"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

Blockwise causal / sliding-window attention with online softmax:
  grid = (batch*heads, n_q_blocks, n_kv_blocks)  — the last grid axis is
  sequential on TPU, so the (m, l, acc) running statistics live in VMEM
  scratch and accumulate across kv blocks.

Block sizes are MXU-aligned (multiples of 128 on the q/kv axes; head_dim is
the lane axis). VMEM footprint per program:
  q (bq, hd) + k,v (bk, hd) + scores (bq, bk) f32 + acc (bq, hd) f32
  = 128*128*(2+2+2) + 128*128*4*2  ~= 230 KiB  << 16 MiB VMEM.

The pure-jnp oracle is kernels/ref.py::attention_ref; ops.py exposes the
jit'd wrapper with interpret fallback.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float):
    i = pl.program_id(1)   # q block
    j = pl.program_id(2)   # kv block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bk, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (bq, bk)

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: bool = True,
) -> jax.Array:
    """q, k, v: (BH, S, hd) -> (BH, S, hd). S must divide by the blocks."""
    BH, S, hd = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, S // bq, S // bk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),    # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)
