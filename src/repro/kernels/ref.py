"""Pure-jnp oracles for the Pallas kernels (tested allclose in tests/)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q,k,v: (BH, S, hd) -> (BH, S, hd). Naive full-materialization softmax."""
    BH, S, hd = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mlstm_ref(q, k, v, log_f, i_gate):
    """Naive per-step recurrence. q,k,v: (BH, S, dh); gates: (BH, S).

    C_t = f_t C_{t-1} + i_t k_t v_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = (q_t^T C_t) / max(|n_t . q_t|, 1)
    """
    BH, S, dh = q.shape

    def step(carry, xs):
        C, n = carry
        qt, kt, vt, lf, ig = xs
        f = jnp.exp(lf)[:, None, None]
        C = f * C + ig[:, None, None] * (kt[:, :, None] * vt[:, None, :])
        n = f[:, :, 0] * n + ig[:, None] * kt
        num = jnp.einsum("bde,bd->be", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bd,bd->b", n, qt)), 1.0)
        return (C, n), num / den[:, None]

    C0 = jnp.zeros((BH, dh, dh), jnp.float32)
    n0 = jnp.zeros((BH, dh), jnp.float32)
    xs = (
        q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
        log_f.swapaxes(0, 1), i_gate.swapaxes(0, 1),
    )
    _, hs = jax.lax.scan(step, (C0, n0), xs)
    return hs.swapaxes(0, 1).astype(q.dtype)


def pairwise_dist_ref(x):
    """x: (B, F) -> (B, B)."""
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.sqrt(jnp.maximum(d2, 1e-12))


def int8_roundtrip_ref(x):
    """Per-tensor-scale int8 quantize/dequantize (kernels/quantize.py oracle;
    also the jnp body of core/codec.py: Int8Codec — same op order)."""
    xf = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / s), -127.0, 127.0)
    return (q * s).astype(x.dtype)


def fused_xent_ref(logits, labels):
    """Per-token cross entropy, fp32 stats. logits (T, V); labels (T,)."""
    import jax.numpy as jnp
    import jax

    x = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(x, axis=-1)
    picked = jnp.take_along_axis(x, labels[:, None], axis=-1)[:, 0]
    return lse - picked
