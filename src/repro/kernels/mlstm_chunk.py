"""Chunkwise-parallel mLSTM Pallas kernel (xLSTM matrix-memory cell).

Grid = (B*H, n_chunks); the chunk axis is sequential on TPU, so the carried
matrix memory C (dh, dh) and normalizer n (dh,) live in VMEM scratch and
flow across chunk programs. Per chunk the kernel does three MXU matmuls
(scores = q k^T, intra = (scores*D) v, inter = q C) plus the log-space decay
algebra — the same math as models/ssm.py::_mlstm_chunk_scan (the oracle is
kernels/ref.py::mlstm_ref).

VMEM per program (P=256, dh=256):
  q,k,v (P, dh) f32 x3 + D (P, P) + C (dh, dh) + h (P, dh)  ~= 1.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, lf_ref, ig_ref, h_ref, C_ref, n_ref, *,
                  P: int, dh: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0]                                  # (P, dh) f32
    k = k_ref[0]
    v = v_ref[0]
    lf = lf_ref[0]                                # (P,) log forget gates
    ig = ig_ref[0]                                # (P,) input gates

    cum = jnp.cumsum(lf)                          # log prod f_1..t
    d_in = jnp.exp(cum)[:, None]                  # decay from chunk start
    # intra-chunk decay matrix D[t, s] = exp(cum_t - cum_s) * i_s for s <= t
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (P, P), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (P, P), 1
    )
    D = jnp.where(tri, jnp.exp(diff) * ig[None, :], 0.0)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    intra = jax.lax.dot_general(scores * D, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    C = C_ref[...]
    inter = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) * d_in
    num = intra + inter

    n_intra = jax.lax.dot_general(D, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    n_t = d_in * n_ref[...][None, :] + n_intra    # (P, dh)
    denom = jnp.maximum(jnp.abs(jnp.sum(n_t * q, axis=1, keepdims=True)), 1.0)
    h_ref[0] = (num / denom).astype(h_ref.dtype)

    # carry state to chunk end
    w = jnp.exp(cum[-1] - cum) * ig               # (P,)
    C_ref[...] = jnp.exp(cum[-1]) * C + jax.lax.dot_general(
        k * w[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_ref[...] = jnp.exp(cum[-1]) * n_ref[...] + jnp.sum(k * w[:, None], axis=0)


def mlstm_chunk(
    q: jax.Array, k: jax.Array, v: jax.Array,
    log_f: jax.Array, i_gate: jax.Array,
    *, chunk: int = 256, interpret: bool = True,
) -> jax.Array:
    """q,k,v: (BH, S, dh) f32; log_f, i_gate: (BH, S). Returns h (BH, S, dh)."""
    BH, S, dh = q.shape
    P = min(chunk, S)
    while S % P:
        P -= 1
    grid = (BH, S // P)
    kernel = functools.partial(_mlstm_kernel, P=P, dh=dh)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, P, dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, P, dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, P, dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, P), lambda b, j: (b, j)),
            pl.BlockSpec((1, P), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, P, dh), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, log_f, i_gate)
