"""Scenario library: the paper's Table 1-6 / figure setups as named specs.

Every benchmark module and example builds its experiments from these
factories instead of hand-rolled wiring — the spec IS the protocol
documentation. Each factory returns a plain :class:`repro.api.
ExperimentSpec`; callers refine with ``spec.with_overrides({...})``.

``PRESETS`` maps preset names to zero-argument factories (default
arguments), which is what ``benchmarks/sweep.py --preset`` and the
serialization tests iterate over.
"""
from __future__ import annotations

from repro.api import (CheckpointSpec, ChurnSpec, CodecSpec, DataSpec,
                       EngineSpec, EnvSpec, ExecSpec, ExperimentSpec,
                       ModelSpec, TrainerSpec)


def quickstart(*, rounds: int = 3, clients: int = 4) -> ExperimentSpec:
    """Small DTFL run on the reduced paper ResNet: the 30-second tour."""
    return ExperimentSpec(
        data=DataSpec(clients=clients, samples=600, iid=True),
        model=ModelSpec(cost_model="resnet-110"),
        rounds=rounds,
    )


def table1_static(tier: int | None = 6, *, rounds: int = 30,
                  target: float = 0.75) -> ExperimentSpec:
    """Table 1 protocol: rounds-to-target with EVERY client pinned to one
    static tier (``tier=None``: the FedAvg row) on the 7-tier-capable bench
    ResNet, priced on full ResNet-110."""
    trainer = (TrainerSpec(method="fedavg") if tier is None
               else TrainerSpec(method="dtfl", scheduler=tier))
    return ExperimentSpec(
        model=ModelSpec(arch="resnet-bench", full_size=True,
                        cost_model="resnet-110"),
        data=DataSpec(dataset="cifar10-hard", clients=5, samples=1500,
                      iid=True),
        env=EnvSpec(switch_every=0),
        trainer=trainer,
        rounds=rounds, target_acc=target,
    )


def table3(method: str = "dtfl", *, iid: bool = True, rounds: int = 10,
           target: float = 0.55, topology: str = "server") -> ExperimentSpec:
    """Table 3: time-to-target, DTFL vs the baselines, IID / non-IID.
    ``topology="pairing"`` is the mutual-offload row (``dtfl_pairing`` in
    benchmarks/table3_baselines.py) — same heterogeneity profile, fast
    clients hosting slow clients' far halves."""
    return ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=10, iid=iid),
        trainer=TrainerSpec(method=method, topology=topology),
        rounds=rounds, target_acc=target,
    )


def pairing_demo(*, rounds: int = 8, clients: int = 10,
                 target: float | None = None) -> ExperimentSpec:
    """Mutual-offload tour: DTFL with the pairing topology on the paper's
    heterogeneity profile (fast clients host slow clients' far halves)."""
    return ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=clients, iid=True),
        trainer=TrainerSpec(method="dtfl", scheduler="pairing"),
        rounds=rounds, target_acc=target,
    )


def table4_accuracy(n: int = 10, method: str = "dtfl", *, rounds: int = 8,
                    target: float = 0.5) -> ExperimentSpec:
    """Table 4: simulated time-to-target vs client-pool size."""
    return ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=n, samples=200 * n, iid=True),
        trainer=TrainerSpec(method=method),
        rounds=rounds, target_acc=target,
        participation=max(0.1, 2.0 / n),
    )


def table4_wall(n: int = 10, *, exec_mode: str = "cohort",
                devices: int | None = None,
                chunk_size: int | None = None) -> ExperimentSpec:
    """Table 4 wall-time sweep: many small clients on the micro ResNet —
    the engine-overhead regime (the harness times ``train_round`` itself)."""
    return ExperimentSpec(
        model=ModelSpec(arch="resnet-micro", full_size=True,
                        cost_model="self"),
        data=DataSpec(clients=n, samples=64 * n, batch_size=8, iid=True),
        env=EnvSpec(switch_every=0),
        exec=ExecSpec(mode=exec_mode, devices=devices,
                      chunk_size=chunk_size),
        rounds=8,
    )


def table4_population(population: int = 100_000, *, sample_size: int = 512,
                      chunk_size: int = 64, rounds: int = 3,
                      samples: int = 64) -> ExperimentSpec:
    """Table 4 population regime: a 100k-client lazy registry with a fixed
    512-client sample per round, trained in fixed-size chunks so device and
    host memory stay O(sample), never O(population). ``samples`` is the
    PER-CLIENT dataset size (lazy per-cid pipelines)."""
    return ExperimentSpec(
        model=ModelSpec(arch="resnet-micro", full_size=True,
                        cost_model="self"),
        data=DataSpec(population=population, samples=samples, batch_size=8,
                      iid=True),
        env=EnvSpec(switch_every=0),
        trainer=TrainerSpec(sample_size=sample_size),
        exec=ExecSpec(mode="chunked", chunk_size=chunk_size),
        rounds=rounds,
    )


def table5(alpha: float = 0.0, *, patch_shuffle: bool = False,
           rounds: int = 6) -> ExperimentSpec:
    """Table 5: privacy integration (dcor regularizer / patch shuffling) on
    the intermediate-difficulty noisy task."""
    return ExperimentSpec(
        data=DataSpec(dataset="cifar10-noisy", clients=5, samples=1200,
                      iid=True),
        trainer=TrainerSpec(dcor_alpha=alpha, patch_shuffle=patch_shuffle),
        rounds=rounds,
    )


def table6(codec: str = "identity", *, env: str = "slow10mbps",
           exec_mode: str = "cohort", engine: str = "auto",
           devices: int | None = None, rounds: int = 10,
           target: float = 0.55, clients: int = 6, samples: int = 1200,
           seed: int = 0) -> ExperimentSpec:
    """Table 6 (repo extension): wire codecs on the bandwidth-starved and
    paper profiles — bytes/round + simulated time-to-target."""
    return ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=clients, samples=samples, iid=False),
        env=EnvSpec(profiles=env),
        engine=EngineSpec(name=engine),
        exec=ExecSpec(mode=exec_mode, devices=devices),
        codec=CodecSpec(name=codec),
        rounds=rounds, target_acc=target, seed=seed,
    )


def fig_async(mode: str = "sync_dtfl", *, rounds: int = 12,
              target: float = 0.55, clients: int = 10, n_groups: int = 3,
              churn: bool = True, seed: int = 0) -> ExperimentSpec:
    """Async-timeline figure: sync DTFL vs async DTFL vs FedAT under churn.
    ``mode``: sync_dtfl | async_dtfl | fedat."""
    method, engine = {
        "sync_dtfl": ("dtfl", "events"),
        "async_dtfl": ("dtfl", "async"),
        "fedat": ("fedat", "auto"),
    }[mode]
    churn_spec = ChurnSpec(drop=0.1, switch=0.1, offline_frac=0.2,
                           seed=seed + 1) if churn else None
    return ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=clients, iid=True),
        trainer=TrainerSpec(method=method),
        engine=EngineSpec(name=engine, n_groups=n_groups, churn=churn_spec),
        rounds=rounds, target_acc=target, seed=seed,
    )


def cifar_paper(method: str = "dtfl", *, rounds: int = 12, clients: int = 10,
                target: float = 0.7) -> ExperimentSpec:
    """The paper's main experiment, CPU-scaled: non-IID Dirichlet(0.5),
    profile switching every 5 rounds, priced on full ResNet-110."""
    return ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=clients, samples=3000),
        env=EnvSpec(switch_every=5),
        trainer=TrainerSpec(method=method),
        rounds=rounds, target_acc=target,
    )


def llm(arch: str = "smollm-360m", *, rounds: int = 6, clients: int = 4,
        seq_len: int = 64) -> ExperimentSpec:
    """DTFL on an assigned transformer arch: split-offloaded federated LM
    training (model-agnosticism demo)."""
    return ExperimentSpec(
        model=ModelSpec(arch=arch),
        data=DataSpec(dataset="lm", clients=clients, batch_size=8,
                      seq_len=seq_len, eval_size=16),
        env=EnvSpec(switch_every=3),
        trainer=TrainerSpec(lr=2e-3),
        rounds=rounds,
    )


def async_churn(engine: str = "auto", *, clients: int = 8, rounds: int = 6,
                n_groups: int = 2, churn: bool = False) -> ExperimentSpec:
    """The event-engine tour setup (examples/async_churn.py): one 8-client
    DTFL scenario run under rounds / events+churn / async engines."""
    churn_spec = ChurnSpec(drop=0.15, switch=0.15, offline_frac=0.25,
                           seed=1) if churn else None
    return ExperimentSpec(
        data=DataSpec(clients=clients, samples=1600, iid=True, eval_size=256),
        engine=EngineSpec(name=engine, n_groups=n_groups, churn=churn_spec),
        rounds=rounds,
    )


def resume_demo(*, rounds: int = 20, path: str = "/tmp/dtfl_state.npz",
                every: int = 5) -> ExperimentSpec:
    """Checkpointed quickstart: the resumable-training README example."""
    return quickstart(rounds=rounds).with_overrides(
        {"checkpoint.path": path, "checkpoint.every": every})


PRESETS = {
    "quickstart": quickstart,
    "table1_static": table1_static,
    "table3": table3,
    "pairing_demo": pairing_demo,
    "table4_accuracy": table4_accuracy,
    "table4_wall": table4_wall,
    "table4_population": table4_population,
    "table5": table5,
    "table6": table6,
    "fig_async": fig_async,
    "cifar_paper": cifar_paper,
    "llm": llm,
    "async_churn": async_churn,
    "resume_demo": resume_demo,
}
