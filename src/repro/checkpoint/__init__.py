"""Pytree checkpointing via .npz (no orbax in the container).

Flattens arbitrary dict/list/tuple/NamedTuple pytrees with '/'-joined key
paths; restores exact structure from a treedef-free path encoding. Scalars
and numpy/jax arrays round-trip; dtypes preserved.

NamedTuples (``DTFLState``, optimizer ``Optimizer`` pairs, step states) are
encoded with their import path (``n[module.QualName]:i``) and reconstructed
as the ORIGINAL class on load, so ``load(save(x))`` preserves the jax pytree
structure — a plain-tuple round trip would silently change the treedef and
break e.g. ``jax.tree.map(params, restored)``.

Also hosts :func:`pack_rng` / :func:`unpack_rng`: lossless (de)serialization
of ``np.random.Generator`` (PCG64) state as a uint64 vector, used by the
resumable-training envelope so a resumed run continues the exact participant
sampling stream of an uninterrupted one.
"""
from __future__ import annotations

import importlib
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _nt_tag(tree) -> str:
    cls = type(tree)
    return f"n[{cls.__module__}.{cls.__qualname__}]"


# marker child recording an EMPTY container — without it an empty dict/list/
# tuple field contributes no paths and silently vanishes (shifting NamedTuple
# fields) on load. Collides only with a literal dict key "__empty__".
_EMPTY = "__empty__"


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        if not tree:
            out[f"{prefix}d:{_EMPTY}"] = np.zeros(0, np.uint8)
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}d:{k}/"))
    elif isinstance(tree, tuple) and hasattr(tree, "_fields"):  # NamedTuple
        tag = _nt_tag(tree)
        if not tree:
            out[f"{prefix}{tag}:{_EMPTY}"] = np.zeros(0, np.uint8)
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{tag}:{i}/"))
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        if not tree:
            out[f"{prefix}{tag}:{_EMPTY}"] = np.zeros(0, np.uint8)
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{tag}:{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _resolve_namedtuple(path: str):
    mod, _, qual = path.rpartition(".")
    obj = importlib.import_module(mod)
    for part in qual.split("."):
        obj = getattr(obj, part)
    return obj


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    if list(flat) == [""]:
        return flat[""]

    def insert(node: dict, parts: list[str], value):
        head, rest = parts[0], parts[1:]
        if rest:
            node = node.setdefault(head, {})
            insert(node, rest, value)
        else:
            node[head] = value

    root: dict = {}
    for k, v in flat.items():
        insert(root, k.split("/"), v)

    def build(node):
        if not isinstance(node, dict):
            return node
        kinds = {k.split(":", 1)[0] for k in node}
        assert len(kinds) == 1, f"mixed node kinds: {sorted(node)}"
        kind = kinds.pop()
        if set(node) == {f"{kind}:{_EMPTY}"}:
            seq = []                       # empty-container marker
        elif kind == "d":
            return {k.split(":", 1)[1]: build(v) for k, v in node.items()}
        else:
            items = sorted(node.items(), key=lambda kv: int(kv[0].split(":", 1)[1]))
            seq = [build(v) for _, v in items]
        if kind == "d":
            return {}
        if kind == "l":
            return seq
        if kind == "t":
            return tuple(seq)
        assert kind.startswith("n[") and kind.endswith("]"), f"bad node kind {kind!r}"
        cls = _resolve_namedtuple(kind[2:-1])
        return cls(*seq)

    return build(root)


def save(path: str, tree: Any) -> None:
    flat = _flatten(jax.tree.map(np.asarray, tree))
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load(path: str) -> Any:
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat)


# ---------------------------------------------------------------------------
# numpy Generator state <-> uint64 vector (for resumable training envelopes)
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


def pack_rng(gen: np.random.Generator) -> np.ndarray:
    """Serialize a PCG64 Generator's full state as shape-(6,) uint64."""
    st = gen.bit_generator.state
    if st["bit_generator"] != "PCG64":
        raise ValueError(f"only PCG64 generators supported, got {st['bit_generator']}")
    s, inc = st["state"]["state"], st["state"]["inc"]
    return np.array(
        [s >> 64, s & _MASK64, inc >> 64, inc & _MASK64,
         st["has_uint32"], st["uinteger"]],
        dtype=np.uint64,
    )


def unpack_rng(arr) -> np.random.Generator:
    """Rebuild the Generator serialized by :func:`pack_rng` (exact stream)."""
    a = [int(x) for x in np.asarray(arr).reshape(-1)]
    gen = np.random.default_rng(0)
    gen.bit_generator.state = {
        "bit_generator": "PCG64",
        "state": {"state": (a[0] << 64) | a[1], "inc": (a[2] << 64) | a[3]},
        "has_uint32": a[4], "uinteger": a[5],
    }
    return gen
