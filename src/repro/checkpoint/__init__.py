"""Pytree checkpointing via .npz (no orbax in the container).

Flattens arbitrary dict/list/tuple pytrees with '/'-joined key paths;
restores exact structure from a treedef-free path encoding. Scalars and
numpy/jax arrays round-trip; dtypes preserved.
"""
from __future__ import annotations

import io
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}d:{k}/"))
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{tag}:{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    if list(flat) == [""]:
        return flat[""]

    def insert(node: dict, parts: list[str], value):
        head, rest = parts[0], parts[1:]
        if rest:
            node = node.setdefault(head, {})
            insert(node, rest, value)
        else:
            node[head] = value

    root: dict = {}
    for k, v in flat.items():
        insert(root, k.split("/"), v)

    def build(node):
        if not isinstance(node, dict):
            return node
        kinds = {k.split(":", 1)[0] for k in node}
        assert len(kinds) == 1, f"mixed node kinds: {sorted(node)}"
        kind = kinds.pop()
        if kind == "d":
            return {k.split(":", 1)[1]: build(v) for k, v in node.items()}
        items = sorted(node.items(), key=lambda kv: int(kv[0].split(":", 1)[1]))
        seq = [build(v) for _, v in items]
        return seq if kind == "l" else tuple(seq)

    return build(root)


def save(path: str, tree: Any) -> None:
    flat = _flatten(jax.tree.map(np.asarray, tree))
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load(path: str) -> Any:
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat)
