"""deepseek-moe-16b [moe]: 28L d_model=2048 16H d_ff=1408 vocab=102400.

[arXiv:2401.06066] fine-grained MoE: 2 shared + 64 routed experts, top-6,
expert d_ff=1408. kv=16 (MHA). Deviation noted in DESIGN.md: the real
model's first dense block is folded into the uniform MoE stack for scan
homogeneity.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_shared=2816,
    serve_window=8192,
    source="arXiv:2401.06066",
)
