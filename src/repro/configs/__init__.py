"""Config registry: ``get_config(name)`` / ``list_configs()``.

Assigned architectures (public pool) + the paper's own ResNet-56/110 CIFAR setups.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES  # noqa: F401

_ARCH_MODULES = {
    "whisper-base": "whisper_base",
    "granite-3-2b": "granite_3_2b",
    "pixtral-12b": "pixtral_12b",
    "yi-6b": "yi_6b",
    "xlstm-350m": "xlstm_350m",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-67b": "deepseek_67b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "smollm-360m": "smollm_360m",
}

ASSIGNED_ARCHS = list(_ARCH_MODULES)

# Paper-native CNN configs live in repro.configs.resnet_cifar
PAPER_MODELS = ["resnet-56", "resnet-110"]


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def list_configs() -> list[str]:
    return list(_ARCH_MODULES)


def get_input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
