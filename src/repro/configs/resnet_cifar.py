"""Paper-native CNN configs: ResNet-56 / ResNet-110 on CIFAR-shaped inputs.

These reproduce the paper's own experiments (Tables 1-5, Fig 2-3): bottleneck
residual stacks split into 8 modules md1..md8 exactly as Appendix A.5
(Tables 8/9), with avgpool+fc auxiliary heads per tier (Table 10).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ResNetConfig:
    name: str
    # number of bottleneck blocks per stage (3 stages; ResNet-6n+2: n per stage)
    blocks_per_stage: int
    n_classes: int = 10
    width: int = 16          # stem channels; stages are 16/32/64 bottleneck mid-channels
    image_size: int = 32
    n_modules: int = 8
    source: str = "arXiv He et al. 2016; DTFL Appendix A.5"

    @property
    def n_blocks(self) -> int:
        return 3 * self.blocks_per_stage

    def reduced(self) -> "ResNetConfig":
        return ResNetConfig(
            name=self.name + "-reduced",
            blocks_per_stage=1,
            n_classes=self.n_classes,
            width=8,
            image_size=16,
            n_modules=4,
            source=self.source,
        )


RESNET56 = ResNetConfig(name="resnet-56", blocks_per_stage=6)    # 1 stem + 18 bottleneck*3 -> 56 layers
RESNET110 = ResNetConfig(name="resnet-110", blocks_per_stage=12)  # 110 layers

# 7-tier-capable reduced model (6 bottleneck blocks -> md2..md7 non-empty):
# the Table-1 protocol trains THIS at every static tier, priced on ResNet-110
RESNET_BENCH = ResNetConfig(name="resnet-bench", blocks_per_stage=2, width=8,
                            image_size=16, n_modules=8)

# engine-overhead micro model (width-4 / 8px): the table4 wall-time sweep's
# many-small-clients regime where dispatch count, not math, dominates
RESNET_MICRO = ResNetConfig(name="resnet-micro", blocks_per_stage=1, width=4,
                            image_size=8, n_modules=4)


def get_resnet(name: str) -> ResNetConfig:
    return {"resnet-56": RESNET56, "resnet-110": RESNET110,
            "resnet-bench": RESNET_BENCH, "resnet-micro": RESNET_MICRO}[name]
