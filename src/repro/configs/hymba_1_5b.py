"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.

[arXiv:2411.13676] parallel attention + mamba heads inside each block,
ssm_state=16; most attention layers use sliding windows (native long_500k).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    window=1024,
    serve_window=1024,
    source="arXiv:2411.13676",
)
