"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + 1 shared, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    n_shared_experts=1,
    top_k=1,
    d_ff_shared=8192,
    serve_window=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
