"""xlstm-350m [ssm]: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.

[arXiv:2405.04517] sLSTM + mLSTM blocks. d_ff=0 per assignment: blocks are
pre-up-projected mLSTM cells (proj factor 2) without a separate FFN, as in
the xLSTM[7:1] configuration; every 8th block is an sLSTM block.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm_state=256,     # head_dim of the matrix memory (d_model / n_heads)
    slstm_every=8,
    source="arXiv:2405.04517",
)
