"""Architecture config schema + input-shape registry.

Every assigned architecture gets one module in this package defining ``CONFIG``
(the exact assigned spec, citation included) and inheriting ``reduced()`` for the
CPU smoke variant (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    """Static architecture description.

    ``family`` selects the forward implementation:
      dense | moe | ssm | hybrid | encdec (audio) | vlm
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""

    # --- attention ---
    head_dim: int = 0            # 0 -> d_model // n_heads
    window: int = 0              # NATIVE sliding window (hymba); 0 = full attention
    serve_window: int = 0        # ring-buffer window for the long-context serve
                                 # variant (long_500k); 0 = full cache
    rope_theta: float = 10_000.0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_shared: int = 0         # shared-expert FFN width (0 -> d_ff * n_shared)
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0
    slstm_every: int = 0         # xLSTM: every Nth block is sLSTM (0 = none)
    d_conv: int = 4              # mamba-style depthwise conv width

    # --- enc-dec / frontends ---
    n_enc_layers: int = 0
    frontend: str = "none"       # none | audio | vision
    n_frontend_tokens: int = 0   # patch / frame count provided by the stub frontend
    d_frontend: int = 0          # stub embedding dim (0 -> d_model)

    # --- misc ---
    pad_vocab_multiple: int = 0  # pad embed/head rows so vocab shards evenly
                                 # (Megatron-style; padded logits masked)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"

    # --- DTFL tiering ---
    n_modules: int = 8           # paper: 8 modules (md1..md8); tiers split on these

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        if not m:
            return self.vocab
        return ((self.vocab + m - 1) // m) * m

    @property
    def d_ff_shared_resolved(self) -> int:
        if self.n_shared_experts == 0:
            return 0
        return self.d_ff_shared or self.d_ff * self.n_shared_experts

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """CPU smoke variant: same family/topology, tiny sizes."""
        d = min(self.d_model, 128)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        # keep head ratio divisible
        while heads % kv:
            kv -= 1
        upd = dict(
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_modules=2,
            window=min(self.window, 64) if self.window else 0,
            serve_window=min(self.serve_window, 64) if self.serve_window else 0,
        )
        if self.n_experts:
            upd.update(
                n_experts=min(self.n_experts, 4),
                top_k=min(self.top_k, 2),
                d_ff=min(self.d_ff, 2 * d),
                d_ff_shared=min(self.d_ff_shared_resolved, 2 * d),
            )
        if self.ssm_state:
            upd["ssm_state"] = min(self.ssm_state, 8)
        if self.n_enc_layers:
            upd["n_enc_layers"] = 2
        if self.n_frontend_tokens:
            upd["n_frontend_tokens"] = min(self.n_frontend_tokens, 16)
            upd["d_frontend"] = min(self.d_frontend or self.d_model, d)
        return self.replace(**upd)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; tested)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
