"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

[hf:HuggingFaceTB/SmolLM-135M family, 360M member] llama-arch small.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    serve_window=8192,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)
