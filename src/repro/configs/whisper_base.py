"""whisper-base [audio]: enc-dec transformer backbone, conv/mel frontend stubbed.

[arXiv:2212.04356] Whisper base: 6 encoder + 6 decoder layers, d_model=512,
8 heads (MHA -> kv=8), d_ff=2048, vocab=51865. The assignment lists "6L";
we interpret it as the decoder depth with a matching 6-layer encoder
(the canonical whisper-base layout). The mel-spectrogram + conv feature
extractor is a STUB: input_specs() provides precomputed frame embeddings
(1500 frames at d_model, the 30s window after 2x conv stride).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    frontend="audio",
    n_frontend_tokens=1500,
    d_frontend=512,
    serve_window=8192,
    source="arXiv:2212.04356",
)
