"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

[hf:mistralai/Pixtral-12B-2409] pixtral-ViT vision encoder + mistral-nemo
decoder. The ViT + projector is a STUB: input_specs() provides precomputed
patch embeddings (1024 patches = one 1024px image at patch 32) early-fused
into the first P sequence positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=14336,
    vocab=131072,
    frontend="vision",
    n_frontend_tokens=1024,
    d_frontend=1024,
    serve_window=8192,
    source="hf:mistralai/Pixtral-12B-2409",
)
