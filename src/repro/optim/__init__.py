"""Pure-JAX optimizers: SGD, Adam, Yogi (+ plateau LR schedule).

Built from scratch (no optax in the container). The paper uses ADAM with
lr 1e-3 (1e-4 for HAM10000) and a reduce-on-plateau x0.9 schedule; FedYogi
uses the Yogi server optimizer (Reddi et al. 2020).

Optimizer is a (init, update) pair over arbitrary pytrees. The learning rate
is carried inside the state so host-side schedules (plateau) can adjust it
between rounds without recompiling.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState], tuple[Params, OptState]]


# ---------------------------------------------------------------------------
# SGD
# ---------------------------------------------------------------------------

def sgd(lr: float = 0.01, momentum: float = 0.0) -> Optimizer:
    def init(params):
        state = {"lr": jnp.asarray(lr, jnp.float32)}
        if momentum:
            state["mu"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(params, grads, state):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            params = jax.tree.map(lambda p, m: p - state["lr"] * m, params, mu)
            return params, {**state, "mu": mu}
        params = jax.tree.map(lambda p, g: p - state["lr"] * g, params, grads)
        return params, state

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / Yogi
# ---------------------------------------------------------------------------

def _adamlike(lr, b1, b2, eps, yogi: bool) -> Optimizer:
    def init(params):
        return {
            "lr": jnp.asarray(lr, jnp.float32),
            "t": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }

    def update(params, grads, state):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        if yogi:
            # Yogi: v -= (1-b2) * sign(v - g^2) * g^2  (additive, sign-controlled)
            v = jax.tree.map(
                lambda v_, g: v_ - (1 - b2) * jnp.sign(v_ - g * g) * g * g,
                state["v"],
                grads,
            )
        else:
            v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            return p - state["lr"] * mh / (jnp.sqrt(jnp.maximum(vh, 0.0)) + eps)

        params = jax.tree.map(upd, params, m, v)
        return params, {**state, "t": t, "m": m, "v": v}

    return Optimizer(init, update)


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adamlike(lr, b1, b2, eps, yogi=False)


def yogi(lr: float = 1e-2, b1: float = 0.9, b2: float = 0.99, eps: float = 1e-3) -> Optimizer:
    return _adamlike(lr, b1, b2, eps, yogi=True)


def set_lr(opt_state: OptState, lr: float) -> OptState:
    return {**opt_state, "lr": jnp.asarray(lr, jnp.float32)}


def get_lr(opt_state: OptState) -> float:
    return float(opt_state["lr"])


# ---------------------------------------------------------------------------
# reduce-on-plateau schedule (paper A.3: x0.9 when accuracy plateaus)
# ---------------------------------------------------------------------------

class PlateauSchedule:
    def __init__(self, factor: float = 0.9, patience: int = 5, min_delta: float = 1e-3):
        self.factor = factor
        self.patience = patience
        self.min_delta = min_delta
        self.best = -float("inf")
        self.bad = 0

    def step(self, metric: float, lr: float) -> float:
        """Call once per round with the current accuracy; returns the new lr."""
        if metric > self.best + self.min_delta:
            self.best = metric
            self.bad = 0
            return lr
        self.bad += 1
        if self.bad >= self.patience:
            self.bad = 0
            return lr * self.factor
        return lr
