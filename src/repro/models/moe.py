"""Mixture-of-Experts FFN: GShard-style grouped capacity-based top-k dispatch.

Experts are sharded over the ``model`` mesh axis (expert parallelism); under
pjit the dispatch/combine einsums lower to all-to-alls. Shared experts
(DeepSeekMoE / llama4-scout) run densely alongside the routed path.

Tokens are processed in *groups* (GShard's trick): capacity is per-group, so
the dispatch tensor is (G, Tg, E, C) with Tg*E*C bounded by the group size —
O(Tg^2 * k * cf) per group instead of O(T^2 * k * cf) globally. Tokens over
capacity are dropped (combine weight zero), keeping all shapes static.

The one-hot dispatch einsum is the TPU-native (MXU-friendly) baseline; a
sort/gather-based dispatch is the documented hillclimb alternative.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, cdtype, dense_init, mlp_apply, mlp_param_init

GROUP_SIZE = 512  # tokens per dispatch group (perf/memory knob)


def moe_param_init(key, cfg) -> Params:
    d, fe = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], d, cfg.n_experts, scale=0.02),
        "we1": jax.random.normal(ks[1], (cfg.n_experts, d, fe), jnp.float32) / math.sqrt(d),
        "we3": jax.random.normal(ks[2], (cfg.n_experts, d, fe), jnp.float32) / math.sqrt(d),
        "we2": jax.random.normal(ks[3], (cfg.n_experts, fe, d), jnp.float32) / math.sqrt(fe),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_param_init(ks[4], d, cfg.d_ff_shared_resolved)
    return p


def group_shape(n_tokens: int) -> tuple[int, int]:
    tg = min(GROUP_SIZE, n_tokens)
    while n_tokens % tg:
        tg -= 1
    return n_tokens // tg, tg


def capacity(tokens_per_group: int, cfg) -> int:
    cap = int(math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(cap, cfg.top_k)


def moe_apply(x: jax.Array, p: Params, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, load_balance_aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    G, Tg = group_shape(T)
    C = capacity(Tg, cfg)
    dt = cdtype(cfg)
    xg = x.reshape(G, Tg, D)

    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                                # (G,Tg,K)

    # position-in-expert: rank of each (token, k) assignment inside its expert
    # queue. k-th choices are ranked after all (k-1)-th choices (GShard policy).
    dispatch = jnp.zeros((G, Tg, E, C), jnp.float32)
    combine = jnp.zeros((G, Tg, E, C), jnp.float32)
    prior = jnp.zeros((G, 1, E), jnp.int32)  # tokens already queued per expert
    for k in range(K):
        oh = jax.nn.one_hot(topi[..., k], E, dtype=jnp.int32)           # (G,Tg,E)
        pos = jnp.cumsum(oh, axis=1) - oh + prior                       # (G,Tg,E)
        prior = prior + oh.sum(axis=1, keepdims=True)
        pos = jnp.sum(pos * oh, axis=-1)                                # (G,Tg)
        keep = (pos < C) & (topi[..., k] >= 0)
        slot = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=jnp.float32)  # (G,Tg,C+..)
        sel = jax.nn.one_hot(topi[..., k], E, dtype=jnp.float32) * keep[..., None]
        d_k = sel[..., :, None] * slot[..., None, :]                    # (G,Tg,E,C)
        dispatch = dispatch + d_k
        combine = combine + d_k * topv[..., k][..., None, None]

    # ---- expert computation (E sharded on the model axis) ----
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), xg.astype(dt))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["we1"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["we3"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", h, p["we2"].astype(dt))           # (G,E,C,D)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), ye)

    out = y.reshape(B, S, D).astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + mlp_apply(x, p["shared"], cfg)

    # Switch-style load-balance auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))                                   # (E,)
    fe_frac = jnp.mean(
        jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E * jnp.sum(me * fe_frac)
    return out, aux


# ===========================================================================
# gather-based dispatch (perf alternative, EXPERIMENTS.md §Perf):
# replaces the O(Tg * E * C) one-hot dispatch MATMULS with scatter/gather
# index plumbing — ~25% less MoE-layer compute, memory-bound instead of
# MXU-bound. Same capacity semantics (drops beyond C), same outputs up to
# dropped-token sets.
# ===========================================================================

def moe_apply_gather(x: jax.Array, p: Params, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, load_balance_aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    G, Tg = group_shape(T)
    C = capacity(Tg, cfg)
    dt = cdtype(cfg)
    xg = x.reshape(G, Tg, D)

    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                       # (G,Tg,K)

    # position-in-expert per (token, k), GShard rank order
    pos = jnp.zeros((G, Tg, K), jnp.int32)
    prior = jnp.zeros((G, 1, E), jnp.int32)
    for k in range(K):
        oh = jax.nn.one_hot(topi[..., k], E, dtype=jnp.int32)
        rank = jnp.cumsum(oh, axis=1) - oh + prior
        prior = prior + oh.sum(axis=1, keepdims=True)
        pos = pos.at[..., k].set(jnp.sum(rank * oh, axis=-1))
    keep = pos < C                                             # (G,Tg,K)

    # scatter token ids into the (E, C) expert queues, then gather inputs
    tok_ids = jnp.broadcast_to(jnp.arange(Tg)[None, :, None], (G, Tg, K))
    slot = jnp.where(keep, topi * C + pos, E * C)              # flat queue slot
    queue = jnp.full((G, E * C + 1), 0, jnp.int32)
    queue = jax.vmap(lambda q, s, t: q.at[s].set(t))(
        queue, slot.reshape(G, -1), tok_ids.reshape(G, -1)
    )[:, : E * C]                                              # (G, E*C)
    xe = jnp.take_along_axis(
        xg.astype(dt), queue[..., None].astype(jnp.int32), axis=1
    ).reshape(G, E, C, D)                                      # gather (all-to-all)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["we1"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["we3"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", h, p["we2"].astype(dt))  # (G,E,C,D)

    # combine: gather each token's K expert outputs back and weight them
    flat_ye = ye.reshape(G, E * C, D)
    safe_slot = jnp.minimum(slot, E * C - 1)
    picked = jax.vmap(lambda y, s: y[s])(flat_ye, safe_slot.reshape(G, -1))
    picked = picked.reshape(G, Tg, K, D)
    w = (topv * keep).astype(dt)
    y = jnp.einsum("gtk,gtkd->gtd", w, picked)

    out = y.reshape(B, S, D).astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + mlp_apply(x, p["shared"], cfg)
    me = jnp.mean(probs, axis=(0, 1))
    fe_frac = jnp.mean(jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * fe_frac)
    return out, aux
