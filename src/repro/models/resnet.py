"""Paper-native ResNet-56/110 (bottleneck) with the DTFL md1..md8 modules.

Faithful to DTFL Appendix A.5 (Tables 8/9/10):
  md1  = stem conv (3->16) [+ maxpool]
  md2  = stage-1 first half (incl. the 16->64 downsample bottleneck)
  md3  = stage-1 second half
  md4  = stage-2 first half (64->128, stride 2)
  md5  = stage-2 second half
  md6  = stage-3 first half (128->256, stride 2)
  md7  = stage-3 second half
  md8  = avgpool + fc
Auxiliary network per tier = avgpool + fc(channels_of_split -> n_classes),
exactly Table 10.

Deviation (DESIGN.md §8): BatchNorm is replaced with GroupNorm(8) so
federated averaging needs no running-stats bookkeeping — a standard FL
substitution; the paper's own FedMA/BN discussion is unaffected.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def conv_init(key, k: int, cin: int, cout: int) -> jax.Array:
    fan_in = k * k * cin
    return jax.random.normal(key, (k, k, cin, cout), jnp.float32) * math.sqrt(2.0 / fan_in)


def conv(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """SAME conv as im2col + matmul (exactly equals lax.conv_general_dilated).

    Expressed with slices/pad/dot instead of the conv primitive so that the
    cohort engine's per-client ``jax.vmap`` lowers to batched GEMMs; vmapping
    the conv primitive over per-client weights produces grouped convolutions
    that XLA:CPU executes far slower than the equivalent batched matmuls.
    """
    k, _, cin, cout = w.shape
    _, H, W, _ = x.shape
    if k == 1:
        return x[:, ::stride, ::stride, :] @ w.reshape(cin, cout)
    oh, ow = -(-H // stride), -(-W // stride)
    ph = max((oh - 1) * stride + k - H, 0)
    pw = max((ow - 1) * stride + k - W, 0)
    xp = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0)))
    cols = [
        xp[:, i : i + stride * (oh - 1) + 1 : stride,
            j : j + stride * (ow - 1) + 1 : stride, :]
        for i in range(k)
        for j in range(k)
    ]
    return jnp.concatenate(cols, axis=-1) @ w.reshape(k * k * cin, cout)


def groupnorm(x: jax.Array, scale, bias, groups: int = 8, eps: float = 1e-5) -> jax.Array:
    N, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(N, H, W, g, C // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(N, H, W, C) * scale + bias


def gn_init(c: int) -> Params:
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


# ---------------------------------------------------------------------------
# bottleneck block
# ---------------------------------------------------------------------------

def bottleneck_init(key, cin: int, mid: int, cout: int, downsample: bool) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "conv1": conv_init(ks[0], 1, cin, mid),
        "gn1": gn_init(mid),
        "conv2": conv_init(ks[1], 3, mid, mid),
        "gn2": gn_init(mid),
        "conv3": conv_init(ks[2], 1, mid, cout),
        "gn3": gn_init(cout),
    }
    if downsample:
        p["down"] = conv_init(ks[3], 1, cin, cout)
    return p


def bottleneck_apply(x: jax.Array, p: Params, stride: int) -> jax.Array:
    h = jax.nn.relu(groupnorm(conv(x, p["conv1"]), **p["gn1"]))
    h = jax.nn.relu(groupnorm(conv(h, p["conv2"], stride), **p["gn2"]))
    h = groupnorm(conv(h, p["conv3"]), **p["gn3"])
    if "down" in p:
        x = conv(x, p["down"], stride)
    return jax.nn.relu(x + h)


# ---------------------------------------------------------------------------
# full network
# ---------------------------------------------------------------------------

def _block_plan(cfg) -> list[dict]:
    """One entry per bottleneck block: channels, stride, module id (2..7)."""
    n = cfg.blocks_per_stage
    w = cfg.width
    plan = []
    cin = w
    for stage, (mid, cout, stride) in enumerate(
        [(w, 4 * w, 1), (2 * w, 8 * w, 2), (4 * w, 16 * w, 2)]
    ):
        for i in range(n):
            plan.append(
                dict(
                    cin=cin,
                    mid=mid,
                    cout=cout,
                    stride=stride if i == 0 else 1,
                    down=(i == 0),
                    module=2 + 2 * stage + (0 if i < max(1, n // 2) else 1),
                )
            )
            cin = cout
    return plan


def init(key, cfg) -> Params:
    plan = _block_plan(cfg)
    ks = jax.random.split(key, len(plan) + 2)
    return {
        "stem": {"conv": conv_init(ks[0], 3, 3, cfg.width), "gn": gn_init(cfg.width)},
        "blocks": [
            bottleneck_init(ks[i + 1], b["cin"], b["mid"], b["cout"], b["down"])
            for i, b in enumerate(plan)
        ],
        "fc": {
            "w": jax.random.normal(ks[-1], (16 * cfg.width, cfg.n_classes), jnp.float32) * 0.01,
            "b": jnp.zeros((cfg.n_classes,), jnp.float32),
        },
    }


def module_of_block(cfg, i: int) -> int:
    return _block_plan(cfg)[i]["module"]


def n_blocks_in_modules(cfg, upto_module: int) -> int:
    """Number of bottleneck blocks contained in modules md2..md{upto}."""
    return sum(1 for b in _block_plan(cfg) if b["module"] <= upto_module)


def forward_features(params: Params, cfg, images: jax.Array, upto_module: int = 8) -> jax.Array:
    """Run stem + blocks of modules <= upto_module. images: (B,H,W,3)."""
    x = jax.nn.relu(groupnorm(conv(images, params["stem"]["conv"]), **params["stem"]["gn"]))
    for bp, plan in zip(params["blocks"], _block_plan(cfg)):
        if plan["module"] > upto_module:
            break
        x = bottleneck_apply(x, bp, plan["stride"])
    return x


def head_apply(params: Params, x: jax.Array) -> jax.Array:
    pooled = x.mean(axis=(1, 2))
    return pooled @ params["fc"]["w"] + params["fc"]["b"]


def forward(params: Params, cfg, images: jax.Array) -> jax.Array:
    return head_apply(params, forward_features(params, cfg, images, 8))


# ---------------------------------------------------------------------------
# DTFL split: client modules [1..m], server modules (m..8], aux = avgpool+fc
# (tree split/merge mechanics live in core/splitting.py; boundary policy here)
# ---------------------------------------------------------------------------

def client_forward(client: Params, cfg, images: jax.Array) -> jax.Array:
    x = jax.nn.relu(groupnorm(conv(images, client["stem"]["conv"]), **client["stem"]["gn"]))
    plan = _block_plan(cfg)
    for bp, pl in zip(client["blocks"], plan):
        x = bottleneck_apply(x, bp, pl["stride"])
    return x


def server_forward(server: Params, cfg, z: jax.Array, tier_module: int) -> jax.Array:
    plan = _block_plan(cfg)[n_blocks_in_modules(cfg, tier_module):]
    x = z
    for bp, pl in zip(server["blocks"], plan):
        x = bottleneck_apply(x, bp, pl["stride"])
    return head_apply({"fc": server["fc"]}, x)


def aux_channels(cfg, tier_module: int) -> int:
    """Channel width at the output of module ``tier_module`` (Table 10 fc input)."""
    nb = n_blocks_in_modules(cfg, tier_module)
    if nb == 0:
        return cfg.width
    return _block_plan(cfg)[nb - 1]["cout"]


def aux_init(key, cfg, tier_module: int) -> Params:
    c = aux_channels(cfg, tier_module)
    return {
        "w": jax.random.normal(key, (c, cfg.n_classes), jnp.float32) * 0.01,
        "b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }


def aux_apply(aux: Params, z: jax.Array) -> jax.Array:
    pooled = z.mean(axis=(1, 2))  # avgpool
    return pooled @ aux["w"] + aux["b"]
