"""Recurrent sequence mixers: mLSTM / sLSTM (xLSTM) and Mamba-style S6.

Training uses chunkwise-parallel forms (TPU adaptation: the per-step
recurrences of the GPU reference become chunked scans whose intra-chunk work
is MXU-shaped matmuls); decoding uses the O(1)-state recurrences.

Simplifications vs the papers (documented in DESIGN.md):
  * mLSTM uses sigmoid input/forget gates (the exp-gate + stabilizer variant
    adds bookkeeping without changing system structure). Decay handled in
    log-space for numerical safety.
  * sLSTM keeps exponential gating with the m-stabilizer but omits the
    post-block FFN (xlstm-350m is assigned with d_ff=0).
  * Mamba drops the depthwise conv's bias and uses a fixed chunk of 16 for
    the chunked selective scan.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, cdtype, dense_init, rmsnorm

MLSTM_CHUNK = 256
MAMBA_CHUNK = 16


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_param_init(key, cfg) -> Params:
    d = cfg.d_model
    di = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "w_up": dense_init(ks[0], d, di),
        "w_gate": dense_init(ks[1], d, di),
        "wq": dense_init(ks[2], di, di),
        "wk": dense_init(ks[3], di, di),
        "wv": dense_init(ks[4], di, di),
        "w_if": dense_init(ks[5], d, 2 * cfg.n_heads, scale=0.02),
        "b_if": jnp.concatenate(
            [jnp.zeros((cfg.n_heads,)), 3.0 * jnp.ones((cfg.n_heads,))]
        ).astype(jnp.float32),
        "w_down": dense_init(ks[6], di, d),
    }


def _mlstm_chunk_scan(q, k, v, log_f, i_gate, C0, n0):
    """Chunkwise-parallel mLSTM.

    q,k,v: (B, H, S, dh); log_f, i_gate: (B, H, S); C0: (B, H, dh, dh);
    n0: (B, H, dh). Returns (h: (B,H,S,dh), C_S, n_S).
    This is the jnp oracle form mirrored by kernels/mlstm_chunk.py.
    """
    B, H, S, dh = q.shape
    P = min(MLSTM_CHUNK, S)
    while S % P:
        P -= 1
    N = S // P
    rs = lambda x: x.reshape(B, H, N, P, *x.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)
    # after rs: leading chunk axis: (N, B, H, P, ...)
    qc, kc, vc = rs(q), rs(k), rs(v)
    lfc, igc = rs(log_f), rs(i_gate)

    def body(carry, xs):
        C, n = carry                            # (B,H,dh,dh), (B,H,dh)
        qb, kb, vb, lf, ig = xs                 # (B,H,P,dh) ... (B,H,P)
        cum = jnp.cumsum(lf, axis=-1)           # (B,H,P) log prod f_1..t
        # decay from chunk start to t (inclusive of f_t)
        d_in = jnp.exp(cum)                     # multiplies carried state
        # intra-chunk decay matrix D[t,s] = exp(cum_t - cum_s) * i_s, s <= t
        diff = cum[..., :, None] - cum[..., None, :]
        mask = jnp.tril(jnp.ones((P, P), bool))
        D = jnp.where(mask, jnp.exp(diff) * ig[..., None, :], 0.0)
        scores = jnp.einsum("bhtd,bhsd->bhts", qb, kb)  # (B,H,P,P)
        intra = jnp.einsum("bhts,bhse->bhte", scores * D, vb)
        inter = jnp.einsum("bhde,bhtd->bhte", C, qb) * d_in[..., None]
        num = intra + inter
        # normalizer n_t = decay * n0 + sum_s (decay ratio) i_s k_s
        n_intra = jnp.einsum("bhts,bhsd->bhtd", D, kb)
        n_t = d_in[..., None] * n[..., None, :] + n_intra
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", n_t, qb)), 1.0)
        h = num / denom[..., None]
        # state update to chunk end
        w = jnp.exp(cum[..., -1:] - cum)        # (B,H,P) decay from t to end
        C_new = jnp.exp(cum[..., -1])[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w * ig, kb, vb
        )
        n_new = jnp.exp(cum[..., -1])[..., None] * n + jnp.einsum("bhs,bhsd->bhd", w * ig, kb)
        return (C_new, n_new), h

    (C_f, n_f), hs = jax.lax.scan(body, (C0, n0), (qc, kc, vc, lfc, igc))
    h = hs.swapaxes(1, 2).swapaxes(0, 2).reshape(B, H, S, dh)
    return h, C_f, n_f


def mlstm_apply(x: jax.Array, p: Params, cfg) -> jax.Array:
    """Full-sequence mLSTM block. x: (B, S, D)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dt = cdtype(cfg)
    h = rmsnorm(x, p["ln"], cfg.norm_eps).astype(dt)
    u = h @ p["w_up"].astype(dt)                 # (B,S,di)
    z = h @ p["w_gate"].astype(dt)
    di = u.shape[-1]
    dh = di // H
    q = (u @ p["wq"].astype(dt)).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = (u @ p["wk"].astype(dt)).reshape(B, S, H, dh).transpose(0, 2, 1, 3) / math.sqrt(dh)
    v = (u @ p["wv"].astype(dt)).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    gates = (h @ p["w_if"].astype(dt)).astype(jnp.float32) + p["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)        # (B,S,H) each
    ig = jax.nn.sigmoid(ig).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(fg).transpose(0, 2, 1)
    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    hcell, _, _ = _mlstm_chunk_scan(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), log_f, ig, C0, n0
    )
    hcell = hcell.transpose(0, 2, 1, 3).reshape(B, S, di).astype(dt)
    out = (hcell * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return x + out.astype(x.dtype)


def mlstm_state_init(cfg, batch: int) -> Params:
    H = cfg.n_heads
    dh = 2 * cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
    }


def mlstm_decode(x: jax.Array, p: Params, cfg, state: Params) -> tuple[jax.Array, Params]:
    """One-step mLSTM. x: (B, 1, D)."""
    B = x.shape[0]
    H = cfg.n_heads
    dt = cdtype(cfg)
    h = rmsnorm(x, p["ln"], cfg.norm_eps).astype(dt)[:, 0]          # (B,D)
    u = h @ p["w_up"].astype(dt)
    z = h @ p["w_gate"].astype(dt)
    di = u.shape[-1]
    dh = di // H
    q = (u @ p["wq"].astype(dt)).reshape(B, H, dh).astype(jnp.float32)
    k = (u @ p["wk"].astype(dt)).reshape(B, H, dh).astype(jnp.float32) / math.sqrt(dh)
    v = (u @ p["wv"].astype(dt)).reshape(B, H, dh).astype(jnp.float32)
    gates = (h @ p["w_if"].astype(dt)).astype(jnp.float32) + p["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)                            # (B,H)
    i_t = jax.nn.sigmoid(ig)
    f_t = jax.nn.sigmoid(fg)
    C = f_t[..., None, None] * state["C"] + i_t[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )                                                                 # (B,H,dh,dh) [k x v]
    n = f_t[..., None] * state["n"] + i_t[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", C, q)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    hcell = (num / denom[..., None]).reshape(B, di).astype(dt)
    out = (hcell * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return x + out[:, None].astype(x.dtype), {"C": C, "n": n}


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_param_init(key, cfg) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "w": dense_init(ks[0], d, 4 * d),                    # i,f,z,o pre-acts
        "r": jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32) / math.sqrt(dh),
        "b": jnp.tile(
            jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]),
            (1,),
        ).astype(jnp.float32),
        "w_down": dense_init(ks[2], d, d),
    }


def _slstm_cell(carry, wx, r):
    """carry: dict(h,c,n,m) each (B,H,dh); wx: (B,H,4dh) input pre-acts."""
    h, c, n, m = carry
    rec = jnp.einsum("bhd,hde->bhe", h, r)                   # (B,H,4dh)
    pre = wx + rec
    i_t, f_t, z_t, o_t = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_t)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_apply(x: jax.Array, p: Params, cfg) -> jax.Array:
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    dt = cdtype(cfg)
    hx = rmsnorm(x, p["ln"], cfg.norm_eps).astype(dt)
    wx = ((hx @ p["w"].astype(dt)).astype(jnp.float32) + p["b"]).reshape(B, S, H, 4 * dh)
    zeros = jnp.zeros((B, H, dh), jnp.float32)
    init = (zeros, zeros, zeros, jnp.full((B, H, dh), -jnp.inf, jnp.float32))

    def body(carry, wx_t):
        new = _slstm_cell(carry, wx_t, p["r"].astype(jnp.float32))
        return new, new[0]

    _, hs = jax.lax.scan(body, init, wx.swapaxes(0, 1))      # scan over S
    hs = hs.swapaxes(0, 1).reshape(B, S, D).astype(dt)
    return x + (hs @ p["w_down"].astype(dt)).astype(x.dtype)


def slstm_state_init(cfg, batch: int) -> Params:
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, H, dh), -jnp.inf, jnp.float32)}


def slstm_decode(x: jax.Array, p: Params, cfg, state: Params) -> tuple[jax.Array, Params]:
    B = x.shape[0]
    H = cfg.n_heads
    D = cfg.d_model
    dh = D // H
    dt = cdtype(cfg)
    hx = rmsnorm(x, p["ln"], cfg.norm_eps).astype(dt)[:, 0]
    wx = ((hx @ p["w"].astype(dt)).astype(jnp.float32) + p["b"]).reshape(B, H, 4 * dh)
    carry = (state["h"], state["c"], state["n"], state["m"])
    h, c, n, m = _slstm_cell(carry, wx, p["r"].astype(jnp.float32))
    out = (h.reshape(B, D).astype(dt) @ p["w_down"].astype(dt))[:, None]
    return x + out.astype(x.dtype), {"h": h, "c": c, "n": n, "m": m}


# ===========================================================================
# Mamba-style S6 (hymba's SSM heads)
# ===========================================================================

def mamba_param_init(key, cfg, d_in: int | None = None) -> Params:
    d = d_in or cfg.d_model
    di = d  # hymba: SSM heads operate at model width
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d, 2 * di),
        "conv": jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32) * 0.1,
        "w_bc": dense_init(ks[2], di, 2 * N, scale=0.02),
        "w_dt": dense_init(ks[3], di, di, scale=0.02),
        "b_dt": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], di, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, di); w: (k, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))


def mamba_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + b_t, chunked associative scan.

    a, b: (B, S, di, N); h0: (B, di, N). Returns (h all steps, h_last)."""
    B, S, di, N = a.shape
    P = min(MAMBA_CHUNK, S)
    while S % P:
        P -= 1
    n = S // P
    ar = a.reshape(B, n, P, di, N).swapaxes(0, 1)
    br = b.reshape(B, n, P, di, N).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, bl * ar_ + br_

    def body(h, xs):
        ac, bc = xs                                   # (B,P,di,N)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = aa * h[:, None] + bb                     # (B,P,di,N)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(body, h0, (ar, br))
    hs = hs.swapaxes(0, 1).reshape(B, S, di, N)
    return hs, h_last


def mamba_apply(x: jax.Array, p: Params, cfg) -> jax.Array:
    """Full-sequence S6. x: (B, S, D) -> (B, S, D) (no residual; caller adds).

    The (B, S, di, N) discretized a/b tensors are never materialized for the
    full sequence: the scan over time chunks computes them per chunk (the
    associative scan runs inside the chunk), keeping the working set
    O(B * P * di * N)."""
    B, S, D = x.shape
    dt = cdtype(cfg)
    N = cfg.ssm_state
    u = x.astype(dt) @ p["w_in"].astype(dt)
    xs, z = jnp.split(u, 2, axis=-1)                  # (B,S,di) each
    xs = jax.nn.silu(_causal_conv(xs, p["conv"].astype(dt)))
    xf = xs.astype(jnp.float32)
    di = xf.shape[-1]
    A = -jnp.exp(p["a_log"])                          # (di,N)

    P = min(MAMBA_CHUNK, S)
    while S % P:
        P -= 1
    n = S // P
    xc = xf.reshape(B, n, P, di).swapaxes(0, 1)       # (n,B,P,di)

    def combine(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, bl * ar_ + br_

    def body(h, xch):                                  # xch: (B,P,di)
        bc = xch @ p["w_bc"].astype(jnp.float32)
        Bt, Ct = jnp.split(bc, 2, axis=-1)             # (B,P,N)
        dt_t = jax.nn.softplus(xch @ p["w_dt"] + p["b_dt"])   # (B,P,di)
        a = jnp.exp(dt_t[..., None] * A)               # (B,P,di,N)
        b = dt_t[..., None] * Bt[..., None, :] * xch[..., None]
        aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = aa * h[:, None] + bb                      # (B,P,di,N)
        y = jnp.einsum("bpdn,bpn->bpd", hs, Ct)
        return hs[:, -1], y

    _, ys = jax.lax.scan(body, jnp.zeros((B, di, N), jnp.float32), xc)
    y = ys.swapaxes(0, 1).reshape(B, S, di) + p["d_skip"] * xf
    y = (y.astype(dt) * jax.nn.silu(z)) @ p["w_out"].astype(dt)
    return y.astype(x.dtype)


def mamba_state_init(cfg, batch: int, d_in: int | None = None) -> Params:
    di = d_in or cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), jnp.float32),
    }


def mamba_decode(x: jax.Array, p: Params, cfg, state: Params) -> tuple[jax.Array, Params]:
    """One-step S6. x: (B, 1, D)."""
    B = x.shape[0]
    dt = cdtype(cfg)
    u = x.astype(dt)[:, 0] @ p["w_in"].astype(dt)
    xs, z = jnp.split(u, 2, axis=-1)                  # (B,di)
    hist = jnp.concatenate([state["conv"], xs[:, None].astype(jnp.float32)], axis=1)
    w = p["conv"]                                     # (k,di)
    xc = jnp.einsum("bkd,kd->bd", hist, w)
    xc = jax.nn.silu(xc)
    bc = xc @ p["w_bc"]
    Bt, Ct = jnp.split(bc, 2, axis=-1)
    dt_t = jax.nn.softplus(xc @ p["w_dt"] + p["b_dt"])
    A = -jnp.exp(p["a_log"])
    a = jnp.exp(dt_t[..., None] * A)                  # (B,di,N)
    b = dt_t[..., None] * Bt[:, None, :] * xc[..., None]
    h = a * state["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Ct) + p["d_skip"] * xc
    y = (y.astype(dt) * jax.nn.silu(z)) @ p["w_out"].astype(dt)
    new_state = {"h": h, "conv": hist[:, 1:]}
    return y[:, None].astype(x.dtype), new_state
