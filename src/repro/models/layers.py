"""Core NN layers: norms, RoPE, GQA attention (train/prefill/decode), MLP.

All functions are pure; parameters are plain dict pytrees. Stacked-layer
parameters carry a leading layer axis and are consumed through ``lax.scan``
in transformer.py. Compute dtype policy: params fp32, matmuls in
``cfg.dtype`` (bf16 by default), softmax / normalization statistics in fp32.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict
_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def embed_init(key, vocab: int, d: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * gamma).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (S,) or (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]                         # broadcast over heads
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention params
# ---------------------------------------------------------------------------

def attn_param_init(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd),
        "wk": dense_init(ks[1], d, kv * hd),
        "wv": dense_init(ks[2], d, kv * hd),
        "wo": dense_init(ks[3], h * hd, d, scale=1.0 / math.sqrt(h * hd)),
    }


# ---------------------------------------------------------------------------
# attention math. GQA kv heads are materialized to H heads ("repeat") and the
# head axis is sharded over the model mesh axis (shardctx "heads"); explicit
# repeat keeps every tensor head-sharded with at most the GSPMD padding waste
# of non-divisible head counts. The no-repeat grouped variant is a perf lever.
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, H: int) -> jax.Array:
    KV = k.shape[2]
    if KV == H:
        return k
    return jnp.repeat(k, H // KV, axis=2)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_offset: int | jax.Array = 0,
    q_chunk: int = 1024,
) -> jax.Array:
    """Dense softmax attention, causal and sliding-window masking.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Returns (B, Sq, H, hd).
    Processes queries in chunks of ``q_chunk`` through ``lax.scan`` (exact —
    softmax rows are complete per chunk) so the score tensor never exceeds
    O(q_chunk * Sk) per head: the jnp analogue of the flash-attention
    blocking used by the Pallas kernel (kernels/flash_attention.py).
    """
    from repro.models.shardctx import constrain, get_setting

    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    # Two layouts (EXPERIMENTS.md §Perf): with a head-sharding context
    # ("heads" spec set) kv heads are repeated to H and the head axis is
    # tensor-parallel; without it (CPU / seqpar preset) attention stays in
    # grouped GQA form — no repeat, k/v move at KV-head size.
    head_sharded = get_setting("heads") is not None
    if head_sharded:
        q = constrain(q, "heads")
        k = constrain(_repeat_kv(k, H), "heads")
        v = constrain(_repeat_kv(v, H), "heads")
        G = 1
        qg = q.reshape(B, Sq, k.shape[2], 1, hd)
    else:
        k = constrain(k, "kv")
        v = constrain(v, "kv")
        G = H // KV
        qg = q.reshape(B, Sq, KV, G, hd)
    kpos = jnp.arange(k.shape[1])

    def chunk_attn(q_chunk_arr, qpos):
        # q_chunk_arr: (B, C, KV, G, hd); qpos: (C,)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", q_chunk_arr, k, preferred_element_type=jnp.float32
        ) * scale
        mask = jnp.ones((qpos.shape[0], k.shape[1]), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, v)

    qc_override = get_setting("q_chunk")
    if qc_override is not None:
        q_chunk = int(qc_override)
    if Sq % q_chunk:
        # non-multiple sequence (e.g. whisper's 1500 frames): largest divisor
        qc = q_chunk
        while Sq % qc:
            qc -= 1
        q_chunk = Sq if qc < 64 else qc
    if Sq <= q_chunk:
        qpos = q_offset + jnp.arange(Sq)
        out = chunk_attn(qg, qpos)
    else:
        n = Sq // q_chunk
        qs = qg.reshape(B, n, q_chunk, *qg.shape[2:]).transpose(1, 0, 2, 3, 4, 5)
        pos = (q_offset + jnp.arange(Sq)).reshape(n, q_chunk)

        def body(_, xs):
            qc, pc = xs
            return None, chunk_attn(qc, pc)

        _, outs = jax.lax.scan(body, None, (qs, pos))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, *qg.shape[2:])
    return out.reshape(B, Sq, H, hd)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    ring: bool,
) -> jax.Array:
    """Single-token attention against a (ring-buffer) KV cache.

    q: (B, 1, H, hd); caches: (B, W, KV, hd); pos: scalar int32 — the absolute
    position of the *current* token (already written into the cache).
    When ``ring`` is True the cache is a ring buffer holding the last W
    positions; otherwise slot i holds absolute position i.
    """
    B, _, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale                                         # (B,KV,G,1,W)
    slots = jnp.arange(W)
    if ring:
        valid = slots <= jnp.minimum(pos, W - 1)      # before wrap only pos+1 slots live
    else:
        valid = slots <= pos
    s = jnp.where(valid[None, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)  # (B,1,KV,G,hd)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# attention blocks (projection + rope + attention), train and decode paths
# ---------------------------------------------------------------------------

def attn_apply(
    x: jax.Array,
    p: Params,
    cfg,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_source: jax.Array | None = None,
    use_rope: bool = True,
) -> jax.Array:
    """Full-sequence attention. kv_source != None -> cross attention."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    dt = cdtype(cfg)
    xv = x.astype(dt)
    src = (kv_source if kv_source is not None else x).astype(dt)
    q = (xv @ p["wq"].astype(dt)).reshape(B, S, cfg.n_heads, hd)
    k = (src @ p["wk"].astype(dt)).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    v = (src @ p["wv"].astype(dt)).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    if use_rope and kv_source is None:
        pos = jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    w = cfg.window if window is None else window
    out = attention(q, k, v, causal=causal and kv_source is None, window=w or 0)
    return (out.reshape(B, S, -1) @ p["wo"].astype(dt)).astype(x.dtype)


def attn_decode_apply(
    x: jax.Array,
    p: Params,
    cfg,
    cache: Params,
    pos: jax.Array,
    *,
    ring: bool,
) -> tuple[jax.Array, Params]:
    """One-token attention; writes k/v into cache slot pos (ring: pos % W)."""
    from repro.models.shardctx import constrain

    B, S1, D = x.shape
    assert S1 == 1
    hd = cfg.resolved_head_dim
    dt = cdtype(cfg)
    xv = x.astype(dt)
    # Serve presets: "dec_qkv_pre" keeps the projection output sharded like
    # the (model-sharded) weights, then "dec_qkv" reshards the tiny one-token
    # q/k/v (an all-gather of KBs). Without the double constraint GSPMD
    # propagates the replicated layout back into per-layer WEIGHT gathers.
    def _proj(w):
        y = (xv @ w.astype(dt)).reshape(B, 1, -1, hd)
        return constrain(constrain(y, "dec_qkv_pre"), "dec_qkv")

    q = _proj(p["wq"])
    k = _proj(p["wk"])
    v = _proj(p["wv"])
    posv = pos[None] if pos.ndim == 0 else pos
    q = apply_rope(q, posv.astype(jnp.float32), cfg.rope_theta)
    k = apply_rope(k, posv.astype(jnp.float32), cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = (pos % W) if ring else pos
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    out = decode_attention(q, k_cache, v_cache, pos, ring=ring)
    y = (out.reshape(B, 1, -1) @ p["wo"].astype(dt)).astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache}


def cross_attn_decode_apply(x, p, cfg, xk, xv_):
    """Cross-attention for decode: keys/values precomputed from encoder output.

    xk, xv_: (B, P, KV, hd)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    dt = cdtype(cfg)
    q = (x.astype(dt) @ p["wq"].astype(dt)).reshape(B, 1, cfg.n_heads, hd)
    out = decode_attention(q, xk.astype(dt), xv_.astype(dt),
                           jnp.asarray(xk.shape[1] - 1), ring=False)
    return (out.reshape(B, 1, -1) @ p["wo"].astype(dt)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_param_init(key, d: int, f: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], d, f),
        "w3": dense_init(ks[1], d, f),
        "w2": dense_init(ks[2], f, d),
    }


def mlp_apply(x: jax.Array, p: Params, cfg) -> jax.Array:
    dt = cdtype(cfg)
    h = x.astype(dt)
    up = jax.nn.silu(h @ p["w1"].astype(dt)) * (h @ p["w3"].astype(dt))
    return (up @ p["w2"].astype(dt)).astype(x.dtype)
