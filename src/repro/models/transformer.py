"""Block definitions per architecture family + the scanned layer stack.

All layer parameters are *stacked* on a leading layer axis and consumed via
``lax.scan``; a DTFL tier is a slice index into that axis (core/tiering.py).

Block kinds:
  dense   : GQA attention + SwiGLU MLP
  moe     : GQA attention + (shared + routed top-k) MoE FFN
  ssm     : xLSTM block — per-layer flag selects mLSTM or sLSTM cell
  hybrid  : hymba block — parallel attention + mamba heads, fused, then MLP
  enc     : bidirectional attention + MLP (whisper encoder)
  dec     : causal self-attn + cross-attn + MLP (whisper decoder)
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.shardctx import constrain
from repro.models.layers import (
    Params,
    attn_apply,
    attn_decode_apply,
    attn_param_init,
    cdtype,
    cross_attn_decode_apply,
    dense_init,
    mlp_apply,
    mlp_param_init,
    rmsnorm,
)


def block_kind(cfg) -> str:
    return {
        "dense": "dense",
        "vlm": "dense",
        "moe": "moe",
        "ssm": "ssm",
        "hybrid": "hybrid",
        "encdec": "dec",
    }[cfg.family]


# ===========================================================================
# per-block init
# ===========================================================================

def block_init(key, cfg, kind: str) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if kind in ("dense", "enc"):
        return {
            "ln1": jnp.ones((d,), jnp.float32),
            "attn": attn_param_init(ks[0], cfg),
            "ln2": jnp.ones((d,), jnp.float32),
            "mlp": mlp_param_init(ks[1], d, cfg.d_ff),
        }
    if kind == "moe":
        return {
            "ln1": jnp.ones((d,), jnp.float32),
            "attn": attn_param_init(ks[0], cfg),
            "ln2": jnp.ones((d,), jnp.float32),
            "moe": moe_lib.moe_param_init(ks[1], cfg),
        }
    if kind == "ssm":
        return {
            "mlstm": ssm_lib.mlstm_param_init(ks[0], cfg),
            "slstm": ssm_lib.slstm_param_init(ks[1], cfg),
        }
    if kind == "hybrid":
        return {
            "ln1": jnp.ones((d,), jnp.float32),
            "attn": attn_param_init(ks[0], cfg),
            "mamba": ssm_lib.mamba_param_init(ks[1], cfg),
            "beta_attn": jnp.ones((d,), jnp.float32),
            "beta_ssm": jnp.ones((d,), jnp.float32),
            "ln_attn": jnp.ones((d,), jnp.float32),
            "ln_ssm": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
            "mlp": mlp_param_init(ks[2], d, cfg.d_ff),
        }
    if kind == "dec":
        return {
            "ln1": jnp.ones((d,), jnp.float32),
            "attn": attn_param_init(ks[0], cfg),
            "ln_x": jnp.ones((d,), jnp.float32),
            "xattn": attn_param_init(ks[1], cfg),
            "ln2": jnp.ones((d,), jnp.float32),
            "mlp": mlp_param_init(ks[2], d, cfg.d_ff),
        }
    raise ValueError(kind)


def stack_init(key, cfg, kind: str, n_layers: int) -> Params:
    keys = jax.random.split(key, n_layers)
    stacked = jax.vmap(lambda k: block_init(k, cfg, kind))(keys)
    if kind == "ssm" and cfg.slstm_every:
        # float flags: bools can't pass through value_and_grad'd trees
        flags = (jnp.arange(n_layers) % cfg.slstm_every) == (cfg.slstm_every - 1)
        stacked["is_slstm"] = flags.astype(jnp.float32)
    return stacked


# ===========================================================================
# per-block apply (full sequence)
# ===========================================================================

def block_apply(
    x: jax.Array,
    bp: Params,
    cfg,
    kind: str,
    *,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (x_out, moe_aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if kind in ("dense", "enc"):
        causal = kind != "enc"
        x = x + attn_apply(rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg, causal=causal)
        x = x + mlp_apply(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
        return x, zero
    if kind == "moe":
        x = x + attn_apply(rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg, causal=True)
        y, aux = moe_lib.moe_apply(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["moe"], cfg)
        return x + y, aux
    if kind == "ssm":
        if "is_slstm" in bp:
            x = jax.lax.cond(
                bp["is_slstm"] > 0.5,
                lambda x: ssm_lib.slstm_apply(x, bp["slstm"], cfg),
                lambda x: ssm_lib.mlstm_apply(x, bp["mlstm"], cfg),
                x,
            )
        else:
            x = ssm_lib.mlstm_apply(x, bp["mlstm"], cfg)
        return x, zero
    if kind == "hybrid":
        h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
        a = attn_apply(h, bp["attn"], cfg, causal=True, window=cfg.window)
        m = ssm_lib.mamba_apply(h, bp["mamba"], cfg)
        fused = 0.5 * (
            bp["beta_attn"] * rmsnorm(a, bp["ln_attn"], cfg.norm_eps)
            + bp["beta_ssm"] * rmsnorm(m, bp["ln_ssm"], cfg.norm_eps)
        ).astype(x.dtype)
        x = x + fused
        x = x + mlp_apply(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
        return x, zero
    if kind == "dec":
        x = x + attn_apply(rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg, causal=True)
        x = x + attn_apply(
            rmsnorm(x, bp["ln_x"], cfg.norm_eps), bp["xattn"], cfg,
            causal=False, kv_source=enc_out, use_rope=False,
        )
        x = x + mlp_apply(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
        return x, zero
    raise ValueError(kind)


def stack_apply(
    x: jax.Array,
    stacked: Params,
    cfg,
    kind: str,
    *,
    enc_out: jax.Array | None = None,
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Scan x through the stacked blocks. Returns (x, total_moe_aux)."""
    fn = functools.partial(block_apply, cfg=cfg, kind=kind, enc_out=enc_out)
    if remat:
        fn = jax.checkpoint(fn)

    def body(x, bp):
        x, aux = fn(x, bp)
        # pin the carry layout every layer so saved remat residuals stay sharded
        return constrain(x, "act"), aux

    x, auxs = jax.lax.scan(body, x, stacked)
    return x, jnp.sum(auxs)


# ===========================================================================
# per-block decode (one token, carried cache)
# ===========================================================================

def block_cache_init(cfg, kind: str, batch: int, cache_len: int) -> Params:
    """Single-layer cache template (stacked by the caller)."""
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    dt = cdtype(cfg)
    if kind in ("dense", "moe", "enc"):
        return {
            "k": jnp.zeros((batch, cache_len, kvh, hd), dt),
            "v": jnp.zeros((batch, cache_len, kvh, hd), dt),
        }
    if kind == "ssm":
        return {
            "mlstm": ssm_lib.mlstm_state_init(cfg, batch),
            "slstm": ssm_lib.slstm_state_init(cfg, batch),
        }
    if kind == "hybrid":
        return {
            "k": jnp.zeros((batch, cache_len, kvh, hd), dt),
            "v": jnp.zeros((batch, cache_len, kvh, hd), dt),
            "mamba": ssm_lib.mamba_state_init(cfg, batch),
        }
    if kind == "dec":
        return {
            "k": jnp.zeros((batch, cache_len, kvh, hd), dt),
            "v": jnp.zeros((batch, cache_len, kvh, hd), dt),
            "xk": jnp.zeros((batch, cfg.n_frontend_tokens, kvh, hd), dt),
            "xv": jnp.zeros((batch, cfg.n_frontend_tokens, kvh, hd), dt),
        }
    raise ValueError(kind)


def block_decode(
    x: jax.Array,
    bp: Params,
    cache: Params,
    cfg,
    kind: str,
    pos: jax.Array,
    *,
    ring: bool,
) -> tuple[jax.Array, Params, jax.Array]:
    """One-token step for a single block. Returns (x, new_cache, moe_aux)."""
    zero = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe"):
        y, kv = attn_decode_apply(
            rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg,
            {"k": cache["k"], "v": cache["v"]}, pos, ring=ring,
        )
        x = x + y
        h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        if kind == "moe":
            y2, aux = moe_lib.moe_apply(h, bp["moe"], cfg)
        else:
            y2, aux = mlp_apply(h, bp["mlp"], cfg), zero
        return x + y2, {**cache, **kv}, aux
    if kind == "ssm":
        if "is_slstm" in bp:
            def do_slstm(args):
                x, st = args
                y, s = ssm_lib.slstm_decode(x, bp["slstm"], cfg, st["slstm"])
                return y, {**st, "slstm": s}

            def do_mlstm(args):
                x, st = args
                y, s = ssm_lib.mlstm_decode(x, bp["mlstm"], cfg, st["mlstm"])
                return y, {**st, "mlstm": s}

            x, cache = jax.lax.cond(bp["is_slstm"] > 0.5, do_slstm, do_mlstm, (x, cache))
        else:
            x, s = ssm_lib.mlstm_decode(x, bp["mlstm"], cfg, cache["mlstm"])
            cache = {**cache, "mlstm": s}
        return x, cache, zero
    if kind == "hybrid":
        h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
        a, kv = attn_decode_apply(h, bp["attn"], cfg, {"k": cache["k"], "v": cache["v"]}, pos, ring=ring)
        m, mstate = ssm_lib.mamba_decode(h, bp["mamba"], cfg, cache["mamba"])
        fused = 0.5 * (
            bp["beta_attn"] * rmsnorm(a, bp["ln_attn"], cfg.norm_eps)
            + bp["beta_ssm"] * rmsnorm(m, bp["ln_ssm"], cfg.norm_eps)
        ).astype(x.dtype)
        x = x + fused
        x = x + mlp_apply(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
        return x, {**cache, **kv, "mamba": mstate}, zero
    if kind == "dec":
        y, kv = attn_decode_apply(
            rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg,
            {"k": cache["k"], "v": cache["v"]}, pos, ring=ring,
        )
        x = x + y
        x = x + cross_attn_decode_apply(
            rmsnorm(x, bp["ln_x"], cfg.norm_eps), bp["xattn"], cfg, cache["xk"], cache["xv"]
        )
        x = x + mlp_apply(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
        return x, {**cache, **kv}, zero
    raise ValueError(kind)


def stack_decode(
    x: jax.Array,
    stacked: Params,
    cache: Params,
    cfg,
    kind: str,
    pos: jax.Array,
    *,
    ring: bool,
) -> tuple[jax.Array, Params, jax.Array]:
    """Scan one token through all blocks, threading per-layer cache slices."""

    def body(x, xs):
        bp, cl = xs
        x, cl, aux = block_decode(x, bp, cl, cfg, kind, pos, ring=ring)
        return x, (cl, aux)

    x, (new_cache, auxs) = jax.lax.scan(body, x, (stacked, cache))
    return x, new_cache, jnp.sum(auxs)


# ===========================================================================
# prefill: full forward that also emits the KV cache
# ===========================================================================

def block_prefill(x, bp, cfg, kind, *, enc_out=None):
    """Full-seq forward emitting this block's cache (attention k/v or state).

    Used by serve prefill. Returns (x, cache_slice, aux)."""
    # Recompute k/v the same way attn_apply does; to avoid drift we inline a
    # lightweight projection here only for cache emission.
    from repro.models.layers import apply_rope  # local import to avoid cycle

    zero = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "hybrid", "dec"):
        B, S, D = x.shape
        hd = cfg.resolved_head_dim
        dt = cdtype(cfg)
        h = rmsnorm(x, bp["ln1"], cfg.norm_eps).astype(dt)
        k = (h @ bp["attn"]["wk"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
        v = (h @ bp["attn"]["wv"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
        k = apply_rope(k, jnp.arange(S), cfg.rope_theta)
        kv = {"k": k, "v": v}
    else:
        kv = {}
    x, aux = block_apply(x, bp, cfg, kind, enc_out=enc_out)
    return x, kv, aux
