"""Public model API: init / forward / decode, uniform across families.

The parameter tree keeps all transformer blocks stacked on a leading layer
axis so DTFL tiers can split it by slicing (core/tiering.py):

    params = {
      'embed':      (V, D),
      'blocks':     {... leading axis L ...},
      'final_ln':   (D,),
      'lm_head':    (D, V)            # absent when cfg.tie_embeddings
      'front_proj': (d_front, D)      # vlm / audio stub projector
      'enc_blocks': {... axis L_enc}  # encdec
      'enc_ln':     (D,),             # encdec
    }

Batch dict:
    tokens:   (B, S) int32
    frontend: (B, P, d_front) float   # vlm / audio archs only
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import Params, cdtype, dense_init, embed_init, rmsnorm
from repro.models import ssm as ssm_lib
from repro.models.shardctx import constrain


# ===========================================================================
# init
# ===========================================================================

def init(key, cfg) -> Params:
    kind = tfm.block_kind(cfg)
    ks = jax.random.split(key, 6)
    params: Params = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "blocks": tfm.stack_init(ks[1], cfg, kind, cfg.n_layers),
        "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab, scale=0.02)
    if cfg.frontend != "none":
        d_front = cfg.d_frontend or cfg.d_model
        params["front_proj"] = dense_init(ks[3], d_front, cfg.d_model)
    if cfg.family == "encdec":
        params["enc_blocks"] = tfm.stack_init(ks[4], cfg, "enc", cfg.n_enc_layers)
        params["enc_ln"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


# ===========================================================================
# embedding / head
# ===========================================================================

def embed_tokens(params: Params, cfg, batch: dict) -> jax.Array:
    x = params["embed"][batch["tokens"]]                       # (B,S,D) fp32
    if cfg.family == "vlm":
        pe = batch["frontend"].astype(jnp.float32) @ params["front_proj"]
        P = pe.shape[1]
        x = jax.lax.dynamic_update_slice(x, pe.astype(x.dtype), (0, 0, 0))
    return constrain(x.astype(cdtype(cfg)), "act")


def encode(params: Params, cfg, batch: dict) -> jax.Array:
    """Whisper encoder over stubbed audio-frame embeddings."""
    xin = batch["frontend"].astype(jnp.float32) @ params["front_proj"]
    xin = xin.astype(cdtype(cfg))
    enc, _ = tfm.stack_apply(xin, params["enc_blocks"], cfg, "enc")
    return rmsnorm(enc, params["enc_ln"], cfg.norm_eps)


def lm_logits(params: Params, cfg, x: jax.Array) -> jax.Array:
    dt = cdtype(cfg)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps).astype(dt)
    # tied configs fall back to embed^T; DTFL split training unties (the two
    # halves live on different hosts), so a split server tree has lm_head.
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = x @ w.astype(dt)
    if cfg.padded_vocab != cfg.vocab:
        # mask the padded vocab rows out of the softmax
        mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e9)
        logits = logits + mask.astype(logits.dtype)
    # internal constraint (padding allowed) keeps non-divisible vocabs sharded
    return constrain(logits, "logits")


# ===========================================================================
# full forward (training / prefill compute)
# ===========================================================================

def forward(params: Params, cfg, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V) compute-dtype, moe_aux_loss)."""
    kind = tfm.block_kind(cfg)
    enc_out = encode(params, cfg, batch) if cfg.family == "encdec" else None
    x = embed_tokens(params, cfg, batch)
    x, aux = tfm.stack_apply(x, params["blocks"], cfg, kind, enc_out=enc_out)
    return lm_logits(params, cfg, constrain(x, "act")), aux


# ===========================================================================
# DTFL split application (client-side / server-side halves)
# ===========================================================================

def client_forward(client_params: Params, cfg, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Embed + first-s blocks. Returns (z, moe_aux). ``client_params`` comes
    from core.tiering.split_params — its 'blocks' are the first s layers."""
    kind = tfm.block_kind(cfg)
    enc_out = encode(client_params, cfg, batch) if cfg.family == "encdec" else None
    x = embed_tokens(client_params, cfg, batch)
    x, aux = tfm.stack_apply(x, client_params["blocks"], cfg, kind, enc_out=enc_out)
    x = constrain(x, "z")  # the DTFL client->server hand-off boundary
    if enc_out is not None:
        return (x, enc_out), aux
    return x, aux


def server_forward(server_params: Params, cfg, z) -> tuple[jax.Array, jax.Array]:
    """Remaining blocks + head on the received activations."""
    kind = tfm.block_kind(cfg)
    enc_out = None
    if cfg.family == "encdec":
        z, enc_out = z
    z = constrain(z, "z")
    x, aux = tfm.stack_apply(z, server_params["blocks"], cfg, kind, enc_out=enc_out)
    return lm_logits(server_params, cfg, x), aux


def aux_head_init(key, cfg) -> Params:
    """DTFL auxiliary network: norm + linear local head (transformer port of
    the paper's avgpool+fc)."""
    return {
        "ln": jnp.ones((cfg.d_model,), jnp.float32),
        "proj": dense_init(key, cfg.d_model, cfg.padded_vocab, scale=0.02),
    }


def aux_head_apply(aux_params: Params, cfg, z) -> jax.Array:
    if cfg.family == "encdec":
        z, _ = z
    dt = cdtype(cfg)
    h = rmsnorm(z, aux_params["ln"], cfg.norm_eps).astype(dt)
    logits = h @ aux_params["proj"].astype(dt)
    if cfg.padded_vocab != cfg.vocab:
        mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e9)
        logits = logits + mask.astype(logits.dtype)
    return constrain(logits, "logits")


# ===========================================================================
# decode (serving)
# ===========================================================================

def cache_len_for(cfg, seq_len: int, *, long_context: bool) -> int:
    if long_context and cfg.serve_window:
        return min(seq_len, cfg.serve_window)
    if cfg.window:
        return min(seq_len, cfg.window)
    return seq_len


def init_cache(cfg, batch_size: int, seq_len: int, *, long_context: bool = False) -> Params:
    kind = tfm.block_kind(cfg)
    W = cache_len_for(cfg, seq_len, long_context=long_context)
    tmpl = tfm.block_cache_init(cfg, kind, batch_size, W)
    layers = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), tmpl)
    return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params: Params, cfg, token: jax.Array, cache: Params) -> tuple[jax.Array, Params]:
    """token: (B,) int32 — the token at position cache['pos'].

    Returns (logits (B, V), updated cache with pos+1)."""
    kind = tfm.block_kind(cfg)
    pos = cache["pos"]
    x = params["embed"][token][:, None, :].astype(cdtype(cfg))  # (B,1,D)
    W = _attn_cache_len(cache)
    x, new_layers, _ = tfm.stack_decode(
        x, params["blocks"], cache["layers"], cfg, kind, pos, ring=_is_ring(cfg, W)
    )
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {"layers": new_layers, "pos": pos + 1}


def _attn_cache_len(cache: Params) -> int | None:
    layers = cache["layers"]
    if isinstance(layers, dict) and "k" in layers:
        return layers["k"].shape[2]
    return None


def _is_ring(cfg, cache_len: int | None) -> bool:
    if cache_len is None:
        return False
    w = cfg.window or cfg.serve_window
    return bool(w) and cache_len <= w


# ===========================================================================
# analytic parameter counts
# ===========================================================================

def _tree_size(tree) -> int:
    return sum(int(math.prod(l.shape)) for l in jax.tree.leaves(tree))


def count_params_analytic(cfg, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))
    total = _tree_size(shapes)
    if not active_only:
        return total
    if cfg.family == "moe":
        per_expert = 3 * cfg.d_model * cfg.d_ff
        total -= (cfg.n_experts - cfg.top_k) * cfg.n_layers * per_expert
    if cfg.family == "ssm" and cfg.slstm_every:
        n_sl = sum(
            1 for i in range(cfg.n_layers) if i % cfg.slstm_every == cfg.slstm_every - 1
        )
        mk = jax.eval_shape(lambda k: tfm.block_init(k, cfg, "ssm"), jax.random.PRNGKey(0))
        m_sz, s_sz = _tree_size(mk["mlstm"]), _tree_size(mk["slstm"])
        total -= n_sl * m_sz + (cfg.n_layers - n_sl) * s_sz
    return total
