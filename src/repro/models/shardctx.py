"""Activation-sharding context: lets the launcher constrain activation layout
without threading mesh specifics through every model function.

model.py calls ``constrain(x, "act")`` / ``constrain(z, "z")`` at the seams
(embed output, DTFL split boundary, pre-head); outside a context these are
no-ops, so CPU smoke tests never see mesh machinery.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_SPECS: dict[str, object] = {}


@contextlib.contextmanager
def activation_sharding(**specs):
    """e.g. activation_sharding(act=P('data', None, 'model'), z=P('data', None, None))."""
    global _SPECS
    old = dict(_SPECS)
    _SPECS.update(specs)
    try:
        yield
    finally:
        _SPECS = old


def constrain(x, kind: str = "act"):
    spec = _SPECS.get(kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def get_setting(kind: str):
    """Non-sharding knobs riding the same context (e.g. 'q_chunk')."""
    return _SPECS.get(kind)
