"""Discrete-event simulation core: virtual clock + deterministic event queue.

The federation engine (``fed/engine.py``) simulates wall-clock behaviour of
heterogeneous clients without real sleeping: every client/tier completion is
an :class:`Event` on a priority queue ordered by virtual time, and the clock
jumps from event to event. This is what lets one process express synchronous
rounds, FedAT-style asynchronous tier aggregation, and client churn (dropout,
arrival, mid-round profile switches) with identical training math.

Determinism contract (tested in ``tests/test_events.py``):
  * events are ordered by ``(time, seq)`` where ``seq`` is the insertion
    order — simultaneous events pop in the order they were pushed, so a run
    is a pure function of the seeds that produced the pushes;
  * cancellation is lazy (the heap entry is tombstoned, skipped on pop), so
    cancelling never perturbs the order of surviving events.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Iterator


@dataclass
class Event:
    """One scheduled occurrence at virtual ``time``.

    ``payload`` is engine-defined (cid / tier / planned offset / ...).
    ``seq`` breaks time ties deterministically by insertion order.
    """

    time: float
    kind: str
    payload: dict = field(default_factory=dict)
    seq: int = 0
    cancelled: bool = False

    def cancel(self) -> None:
        self.cancelled = True


class EventQueue:
    """Virtual clock + min-heap of events with deterministic tie-breaking."""

    def __init__(self, start_time: float = 0.0):
        self.now = float(start_time)
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0

    # ------------------------------------------------------------------
    def push(self, time: float, kind: str, **payload: Any) -> Event:
        """Schedule ``kind`` at absolute virtual ``time`` (>= now)."""
        if time < self.now:
            raise ValueError(f"cannot schedule into the past: {time} < now={self.now}")
        ev = Event(float(time), kind, payload, seq=self._seq)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        return ev

    def push_in(self, delay: float, kind: str, **payload: Any) -> Event:
        """Schedule ``kind`` ``delay`` virtual seconds from now."""
        return self.push(self.now + float(delay), kind, **payload)

    # ------------------------------------------------------------------
    def pop(self) -> Event | None:
        """Next live event; advances the clock to its time."""
        while self._heap:
            _, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.time
            return ev
        return None

    def peek_time(self) -> float | None:
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def drain_until(self, time: float) -> Iterator[Event]:
        """Pop (and yield) every live event with ``ev.time <= time``, then
        advance the clock to ``time`` even if nothing was due."""
        while True:
            t = self.peek_time()
            if t is None or t > time:
                break
            yield self.pop()
        self.now = max(self.now, float(time))

    def advance_to(self, time: float) -> None:
        """Move the clock forward with no event (e.g. a serial server phase)."""
        if time < self.now:
            raise ValueError(f"clock cannot move backwards: {time} < {self.now}")
        self.now = float(time)

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return sum(0 if ev.cancelled else 1 for _, _, ev in self._heap)

    def empty(self) -> bool:
        # O(1) amortized (peek_time pops tombstones once each), unlike the
        # O(n) live count in __len__ — drain loops call this per event
        return self.peek_time() is None
