"""Tier -> split-point mapping for the transformer port.

The paper divides the global model into 8 "modules" (md1..md8); tier m's
client-side model is modules md1..md_m (Table 10/11). For the transformer
port, modules are 8 ~equal groups of blocks; md8 (the paper's avgpool+fc)
is the final norm + LM head, which always stays server-side, so tiers run
1..7 (M <= n_modules - 1).

This module owns the *policy* (tier -> block boundary); the split/merge
*mechanics* live in :mod:`repro.core.splitting` (shared with the ResNet).
Because block parameters are stacked on a leading layer axis, a tier split
is a constant-time tree slice; merge is a concatenate. Split/merge is
lossless (tested), which is what makes cross-tier FedAvg aggregation exact.
"""
from __future__ import annotations

from repro.core import splitting

Params = dict

# keys that always live client-side (input-adjacent) / server-side
CLIENT_KEYS = splitting.TRANSFORMER.near_keys
SERVER_KEYS = splitting.TRANSFORMER.far_keys


def module_boundaries(n_layers: int, n_modules: int = 8) -> list[int]:
    """Cumulative block counts for md1..md_{n_modules-1}.

    boundary[m] = number of blocks in modules md1..md_{m+1}; the final module
    (head) contains no blocks. Every boundary is >= 1 so each tier's client
    model is non-empty, and <= n_layers - 1 so the server always keeps work.
    """
    n_split = n_modules - 1  # modules that contain blocks
    bounds = []
    for m in range(1, n_split + 1):
        b = round(n_layers * m / n_split)
        b = max(1, min(b, n_layers - 1)) if n_layers > 1 else 1
        bounds.append(b)
    return bounds


def n_tiers(cfg) -> int:
    return cfg.n_modules - 1


def split_layer(cfg, tier: int) -> int:
    """Client-side block count for ``tier`` (1-based, 1..n_tiers)."""
    bounds = module_boundaries(cfg.n_layers, cfg.n_modules)
    if not 1 <= tier <= len(bounds):
        raise ValueError(f"tier {tier} out of range 1..{len(bounds)}")
    return bounds[tier - 1]


def split_params(params: Params, cfg, tier: int) -> tuple[Params, Params]:
    """Split the full parameter tree at ``tier``. Returns (client, server)."""
    return splitting.split_params(params, split_layer(cfg, tier),
                                  splitting.TRANSFORMER)


def merge_params(client: Params, server: Params) -> Params:
    return splitting.merge_params(client, server, splitting.TRANSFORMER)
