"""Communication codecs: what actually travels on DTFL's three wires.

The paper's whole premise is bandwidth-heterogeneous clients (10–100 Mbps
profiles; Algorithm 1 schedules on ``D_size(m)/nu``), and FedAT
(arXiv:2010.05958) shows update compression cuts communicated bytes ~8x with
no accuracy loss. This module makes compression first-class: a :class:`Codec`
is applied to the three real wires a DTFL round has —

  * the per-batch **activation(+label) uplink** ``z``,
  * the per-round **client-model download** (client half + tier aux head),
  * the per-round **client-update upload** (trained client half + aux delta,
    sent as a delta against the downloaded reference),

— inside the jitted cohort programs (``fed/dtfl.py`` / ``fed/base.py``), and
its *true* wire sizes (:class:`WireSizes`) are threaded through the analytic
time model (``core/timemodel.py``) and the dynamic tier scheduler's profile
(``core/scheduler.py``), so re-tiering reacts when compression changes the
compute/communication balance.

Codecs are pure jnp and vmap/shard_map-compatible: ``rt`` (round-trip =
encode + decode on-device; the bytes named by ``nbytes`` are what the encoded
form would occupy on a real wire) maps one tensor, ``tree_rt`` a pytree.
``TopKCodec`` is *stateful*: the client keeps the un-sent residual
(error feedback) and adds it back before the next upload — trainers hold that
state per client and checkpoint it. The int8 path has a fused Pallas
quantize/dequant kernel (``kernels/quantize.py``); the jnp body here is the
bit-equivalent reference used by default on CPU.

Identity is special-cased everywhere: ``tree_rt`` returns its argument
unchanged (so jitted programs trace identically to the pre-codec path) and
:func:`wire_sizes` reproduces the legacy analytic byte model exactly
(the paper's Eq.-5 accounting: z per batch + model download per round for
split training, download + upload for full-model baselines).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

FP32_BYTES = 4.0


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


class Codec:
    """Base codec: identity semantics, fp32 wire pricing."""

    name = "identity"
    is_identity = True
    stateful = False          # True => rt_ef carries client-held error feedback

    # ---- tensor path (jnp, trace-safe, vmap-compatible) ----
    def rt(self, x):
        """Round-trip one tensor through the wire (decode(encode(x)))."""
        return x

    def tree_rt(self, tree):
        if self.is_identity:
            return tree       # structurally unchanged => identical jit trace
        return jax.tree.map(self.rt, tree)

    def down_rt(self, x):
        """Round-trip for the server->client DOWNLOAD wire. Defaults to
        :meth:`rt`; sparsifying codecs override it to identity — top-k is an
        uplink technique (the error feedback compensates only what the
        CLIENT fails to send; truncating the broadcast would zero the
        aggregated global a little more every round, uncompensated), so the
        server ships the dense model and pays dense download bytes."""
        return self.rt(x)

    def tree_down_rt(self, tree):
        if self.is_identity:
            return tree
        return jax.tree.map(self.down_rt, tree)

    def rt_ef(self, x, e):
        """Error-feedback round-trip: compress ``x + e``; the un-sent part
        becomes the next residual. Identity/stateless codecs keep e = 0."""
        c = x + e
        y = self.rt(c)
        return y, c - y

    def tree_rt_ef(self, tree, ef):
        y = jax.tree.map(lambda x, e: self.rt(x + e), tree, ef)
        new_ef = jax.tree.map(lambda x, e, d: (x + e) - d, tree, ef, y)
        return y, new_ef

    # ---- wire pricing (numpy, analytic — never runs the codec) ----
    def nbytes(self, n_elems):
        """Wire bytes for a float tensor (or per-wire aggregate) of
        ``n_elems`` elements. Vectorized over numpy arrays of counts."""
        return FP32_BYTES * np.asarray(n_elems, float)

    def down_nbytes(self, n_elems):
        """Download-wire bytes (matches :meth:`down_rt`'s transform)."""
        return self.nbytes(n_elems)


class IdentityCodec(Codec):
    pass


class Bf16Codec(Codec):
    """Truncate float tensors to bfloat16 on the wire (2 bytes/element)."""

    name = "bf16"
    is_identity = False

    def rt(self, x):
        if not _is_float(x):
            return x
        return x.astype(jnp.bfloat16).astype(x.dtype)

    def nbytes(self, n_elems):
        return 2.0 * np.asarray(n_elems, float)


class Int8Codec(Codec):
    """Per-tensor-scale int8 quantization: s = max|x|/127, q = round(x/s).

    ``use_kernel=True`` dispatches to the fused Pallas quantize/dequant
    kernel (``kernels/ops.int8_roundtrip_op``); the default jnp body is its
    bit-equivalent reference (``kernels/ref.int8_roundtrip_ref``).
    """

    name = "int8"
    is_identity = False

    def __init__(self, use_kernel: bool = False):
        self.use_kernel = use_kernel

    def rt(self, x):
        if not _is_float(x):
            return x
        if self.use_kernel:
            from repro.kernels.ops import int8_roundtrip_op

            return int8_roundtrip_op(x)
        from repro.kernels.ref import int8_roundtrip_ref

        return int8_roundtrip_ref(x)

    def nbytes(self, n_elems):
        # 1 byte/element + one fp32 scale per wire
        return np.asarray(n_elems, float) + FP32_BYTES


class TopKCodec(Codec):
    """Magnitude top-k sparsification with client-held error feedback.

    Keeps the ``ceil(frac * n)`` largest-|x| entries (value + index on the
    wire: 8 bytes each), zeroes the rest. Trainers route uploads through
    ``rt_ef`` so the un-sent mass re-enters the next round's upload — the
    standard convergence fix for sparsified updates. The DOWNLOAD wire is
    NOT sparsified (``down_rt`` = identity, priced dense): error feedback
    lives on the client and cannot compensate a truncated broadcast, which
    would otherwise zero ~(1-frac) of the aggregated global every round.
    """

    is_identity = False
    stateful = True

    def __init__(self, frac: float):
        if not (0.0 < frac <= 1.0):
            raise ValueError(f"topk fraction must be in (0, 1], got {frac}")
        self.frac = float(frac)
        self.name = f"topk{self.frac:g}"

    def _k(self, n: int) -> int:
        return max(1, int(math.ceil(self.frac * n)))

    def rt(self, x):
        if not _is_float(x):
            return x
        flat = x.reshape(-1)
        k = self._k(flat.size)
        if k >= flat.size:
            return x
        _, idx = jax.lax.top_k(jnp.abs(flat.astype(jnp.float32)), k)
        out = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return out.reshape(x.shape)

    def down_rt(self, x):
        return x          # dense broadcast (see class docstring)

    def nbytes(self, n_elems):
        n = np.asarray(n_elems, float)
        k = np.maximum(1.0, np.ceil(self.frac * n))
        return 8.0 * k   # fp32 value + int32 index per kept entry

    def down_nbytes(self, n_elems):
        return FP32_BYTES * np.asarray(n_elems, float)   # dense download


def make_codec(spec: "Codec | str | None") -> Codec:
    """Resolve a CLI/ctor codec spec: None | 'identity' | 'bf16' | 'int8' |
    'topk<frac>' (e.g. ``topk0.05``) | any codec registered with
    ``repro.registry.register_codec`` | a Codec instance."""
    if spec is None:
        return IdentityCodec()
    if isinstance(spec, Codec):
        return spec
    from repro import registry

    return registry.codecs.build(str(spec).strip().lower())


# ---------------------------------------------------------------------------
# upload-wire helpers (shared by the cohort/sharded/loop trainer programs)
# ---------------------------------------------------------------------------

def uplink_rt(codec: Codec, trained, ref):
    """Client-update upload wire over a cohort: ``trained`` has a leading
    client axis, ``ref`` is the single downloaded reference every member
    started from. The update is sent as a delta (far more compressible than
    raw weights), codec'd per client, and reconstructed server-side as
    ``ref + decode(encode(trained - ref))``."""
    if codec.is_identity:
        return trained
    delta = jax.tree.map(lambda t, r: t - r[None], trained, ref)
    dec = jax.vmap(codec.tree_rt)(delta)
    return jax.tree.map(lambda r, d: r[None] + d, ref, dec)


def uplink_rt_ef(codec: Codec, trained, ref, ef):
    """:func:`uplink_rt` with client-held error feedback: ``ef`` (leading
    client axis) is the residual each client failed to send last round;
    returns the reconstructed uploads and the new residuals."""
    delta = jax.tree.map(lambda t, r: t - r[None], trained, ref)
    dec, ef2 = jax.vmap(codec.tree_rt_ef)(delta, ef)
    return jax.tree.map(lambda r, d: r[None] + d, ref, dec), ef2


def uplink_rt_one(codec: Codec, trained, ref, ef=None):
    """Single-client :func:`uplink_rt` / :func:`uplink_rt_ef` (the loop
    execution path); returns ``(upload, new_ef_or_None)``."""
    if codec.is_identity:
        return trained, None
    delta = jax.tree.map(lambda t, r: t - r, trained, ref)
    if ef is None:
        dec = codec.tree_rt(delta)
        new_ef = None
    else:
        dec, new_ef = codec.tree_rt_ef(delta, ef)
    return jax.tree.map(lambda r, d: r + d, ref, dec), new_ef


# ---------------------------------------------------------------------------
# analytic wire sizes (threaded through timemodel + scheduler profiling)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class WireSizes:
    """Codec-true bytes for every wire of a round, per tier.

    ``z_bytes[m]``    — per-batch activation(+label) uplink; labels ride raw.
    ``down_bytes[m]`` — per-round client-model (+aux head) download.
    ``up_bytes[m]``   — per-round client-update upload (delta coding).
    ``full_down`` / ``full_up`` — the full-model baselines' two wires.

    Identity reproduces the legacy analytic accounting bit-for-bit: split
    training prices z + amortized download (the paper's ``D_size``; upload
    unpriced, as in Eq. 5), full-model baselines price download + upload
    (the existing ``2 * full_param_bytes``).
    """

    z_bytes: np.ndarray
    down_bytes: np.ndarray
    up_bytes: np.ndarray
    full_down: float
    full_up: float

    @property
    def param_bytes(self) -> np.ndarray:
        """Per-round parameter-wire total (download + upload) per tier."""
        return self.down_bytes + self.up_bytes

    def comm_bytes(self, tiers, n_batches) -> np.ndarray:
        """Total per-round bytes on all wires for clients at ``tiers``."""
        return (self.z_bytes[np.asarray(tiers, int)] * np.asarray(n_batches, float)
                + self.param_bytes[np.asarray(tiers, int)])

    def uplink_bytes(self, tiers, n_batches) -> np.ndarray:
        """Client->server bytes only (z uplink + update upload)."""
        return (self.z_bytes[np.asarray(tiers, int)] * np.asarray(n_batches, float)
                + self.up_bytes[np.asarray(tiers, int)])


def wire_sizes(costs, codec: "Codec | str | None" = None) -> WireSizes:
    """Build :class:`WireSizes` from a ``TierCostTable``.

    Non-identity codecs price from the table's element counts (``z_elems``,
    ``param_elems``; falls back to bytes/4 for hand-built tables); the wire
    is approximated as one tensor per wire (per-tensor overheads like int8
    scales are O(bytes_per_tensor) and negligible against the payload).
    """
    codec = make_codec(codec)
    z_id = np.asarray(costs.z_bytes, float)
    p_id = np.asarray(costs.client_param_bytes, float)
    if codec.is_identity:
        return WireSizes(
            z_bytes=z_id.copy(), down_bytes=p_id.copy(),
            up_bytes=np.zeros_like(p_id),
            full_down=float(costs.full_param_bytes),
            full_up=float(costs.full_param_bytes),
        )
    have_elems = getattr(costs, "z_elems", None) is not None
    z_elems = (np.asarray(costs.z_elems, float) if have_elems
               else z_id / FP32_BYTES)
    label_b = float(costs.label_bytes) if have_elems else 0.0
    p_elems = (np.asarray(costs.param_elems, float)
               if getattr(costs, "param_elems", None) is not None
               else p_id / FP32_BYTES)
    f_elems = (float(costs.full_param_elems) if getattr(costs, "full_param_elems", 0)
               else float(costs.full_param_bytes) / FP32_BYTES)
    return WireSizes(
        z_bytes=codec.nbytes(z_elems) + label_b,
        down_bytes=codec.down_nbytes(p_elems),
        up_bytes=codec.nbytes(p_elems),
        full_down=float(codec.down_nbytes(f_elems)),
        full_up=float(codec.nbytes(f_elems)),
    )
