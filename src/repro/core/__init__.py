"""DTFL core: tiering, local-loss split training, dynamic tier scheduling."""
from repro.core import aggregation, local_loss, scheduler, tiering, timemodel  # noqa: F401
