"""Simulated heterogeneous environment: resource profiles + analytic per-tier
costs (the paper's Sec. 4.1 simulation, made analytic).

The paper assigns each client a (CPU fraction, Mbps) profile and *simulates*
slowdown; we compute the same times analytically from per-tier FLOP/byte
counts. The scheduler never sees these profiles — it only observes the times
and the communicated ``nu`` (link speed), exactly as in Algorithm 1.

Profiles (paper Sec. 4.1): 4 CPUs/100 Mbps, 2/30, 1/30, 0.2/30, 0.1/10.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

# FLOP/s of "1 CPU" in the simulation; arbitrary unit that sets the
# compute/communication balance to roughly the paper's regime.
UNIT_FLOPS = 125e9
SERVER_FLOPS = 400e9  # the server trains every client's server-side model
BYTES_PER_PARAM = 4


@dataclass(frozen=True)
class ResourceProfile:
    cpus: float
    mbps: float

    @property
    def flops(self) -> float:
        return self.cpus * UNIT_FLOPS

    @property
    def bytes_per_s(self) -> float:
        return self.mbps * 1e6 / 8


PAPER_PROFILES = [
    ResourceProfile(4.0, 100.0),
    ResourceProfile(2.0, 30.0),
    ResourceProfile(1.0, 30.0),
    ResourceProfile(0.2, 30.0),
    ResourceProfile(0.1, 10.0),
]

CASE1_PROFILES = [  # Table 1 case 1
    ResourceProfile(2.0, 30.0),
    ResourceProfile(1.0, 30.0),
    ResourceProfile(0.2, 30.0),
]
CASE2_PROFILES = [  # Table 1 case 2
    ResourceProfile(4.0, 100.0),
    ResourceProfile(1.0, 30.0),
    ResourceProfile(0.1, 10.0),
]


# ---------------------------------------------------------------------------
# per-tier cost tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TierCostTable:
    """Per-batch costs for each tier m (index 0 = tier 1).

    client_flops[m]  : client-side fwd+bwd FLOPs per batch (incl. aux head)
    server_flops[m]  : server-side fwd+bwd FLOPs per batch
    z_bytes[m]       : activation (+label) upload per batch
    client_param_bytes[m] : client-side model download per round

    The ``*_elems`` fields carry raw element counts alongside the identity
    (fp32/bf16) byte pricing, so the communication plane (``core/codec.py:
    wire_sizes``) can price the same wires under any codec; ``label_bytes``
    is the per-batch label payload, which always rides uncompressed.
    """

    client_flops: np.ndarray
    server_flops: np.ndarray
    z_bytes: np.ndarray
    client_param_bytes: np.ndarray
    full_flops: float = 0.0        # fwd+bwd FLOPs/batch of the whole model
    full_param_bytes: float = 0.0  # whole-model parameter bytes
    z_elems: np.ndarray | None = None      # activation elements per batch
    label_bytes: float = 0.0               # raw label bytes per batch
    param_elems: np.ndarray | None = None  # client-side parameter count
    full_param_elems: float = 0.0          # whole-model parameter count

    @property
    def n_tiers(self) -> int:
        return len(self.client_flops)

    def d_size(self, m: int, n_batches: int) -> float:
        """Paper's D_size(m): per-batch transferred bytes (model download
        amortized over the round's batches)."""
        return self.z_bytes[m] + self.client_param_bytes[m] / max(n_batches, 1)


def resnet_tier_costs(cfg, batch_size: int) -> TierCostTable:
    """Analytic conv FLOPs for the paper's ResNet-56/110 module splits."""
    from repro.models import resnet as R

    plan = R._block_plan(cfg)
    hw = cfg.image_size * cfg.image_size

    def block_flops(b, hw_in):
        # three convs (1x1, 3x3, 1x1) + optional downsample, x2 for MACs
        hw_out = hw_in // (b["stride"] ** 2)
        f = 2 * hw_out * (
            b["cin"] * b["mid"] + 9 * b["mid"] * b["mid"] + b["mid"] * b["cout"]
        )
        if b["down"]:
            f += 2 * hw_out * b["cin"] * b["cout"]
        return f, hw_out

    stem_flops = 2 * hw * 3 * cfg.width * 9
    per_block, hws = [], []
    cur = hw
    for b in plan:
        f, cur = block_flops(b, cur)
        per_block.append(f)
        hws.append(cur)

    def params_of(b):
        p = b["cin"] * b["mid"] + 9 * b["mid"] * b["mid"] + b["mid"] * b["cout"]
        if b["down"]:
            p += b["cin"] * b["cout"]
        return p

    n_tiers = cfg.n_modules - 1
    cf, sf, zb, pb, ze, pe = [], [], [], [], [], []
    total_fwd = stem_flops + sum(per_block)
    for tier in range(1, n_tiers + 1):
        nb = R.n_blocks_in_modules(cfg, tier)
        c_fwd = stem_flops + sum(per_block[:nb])
        s_fwd = total_fwd - c_fwd
        cout = R.aux_channels(cfg, tier)
        hw_out = hws[nb - 1] if nb else hw
        cf.append(3.0 * batch_size * (c_fwd + 2 * cout * cfg.n_classes))  # fwd+bwd ~3x
        sf.append(3.0 * batch_size * (s_fwd + 2 * 16 * cfg.width * cfg.n_classes))
        ze.append(batch_size * hw_out * cout)
        zb.append(batch_size * hw_out * cout * BYTES_PER_PARAM + batch_size * 4)
        stem_p = 27 * cfg.width
        c_params = stem_p + sum(params_of(b) for b in plan[:nb]) + cout * cfg.n_classes
        pe.append(c_params)
        pb.append(c_params * BYTES_PER_PARAM)
    full_flops = 3.0 * batch_size * (total_fwd + 2 * 16 * cfg.width * cfg.n_classes)
    full_params = 27 * cfg.width + sum(params_of(b) for b in plan) + 16 * cfg.width * cfg.n_classes
    raw = np.array(cf, float)
    cf = _with_client_overhead(raw)
    overhead = float(cf[0] - raw[0])
    return TierCostTable(
        cf, np.array(sf), np.array(zb), np.array(pb),
        # a full-model client pays the same fixed per-batch overhead
        full_flops=full_flops + overhead,
        full_param_bytes=full_params * BYTES_PER_PARAM,
        z_elems=np.array(ze, float), label_bytes=float(batch_size * 4),
        param_elems=np.array(pe, float), full_param_elems=float(full_params),
    )


# Paper Table 2 (cont.): measured client-side times span only ~3.8x between the
# extreme tiers — the real system has a large fixed per-batch cost (input
# pipeline, framework overhead, aux head). We add a flops-equivalent
# overhead calibrated so tier6/tier1 == 3.81, matching Table 2 exactly.
TABLE2_RATIO = 3.81


def _with_client_overhead(cf: np.ndarray) -> np.ndarray:
    hi = cf[min(5, len(cf) - 1)]
    o = max((hi - TABLE2_RATIO * cf[0]) / (TABLE2_RATIO - 1.0), 0.0)
    return cf + o


def transformer_tier_costs(cfg, batch_size: int, seq_len: int) -> TierCostTable:
    """Per-tier costs for the transformer-family port (6*P*T fwd+bwd rule +
    quadratic attention term)."""
    from repro.core import tiering
    from repro.models import model as M

    tokens = batch_size * seq_len
    n_tiers = tiering.n_tiers(cfg)
    bounds = tiering.module_boundaries(cfg.n_layers, cfg.n_modules)

    per_layer = _layer_params(cfg)
    embed_p = cfg.vocab * cfg.d_model
    head_p = 0 if cfg.tie_embeddings else cfg.vocab * cfg.d_model
    attn_flops = (
        0
        if cfg.family == "ssm"
        else 4 * tokens * min(seq_len, cfg.window or seq_len) * cfg.n_heads * cfg.resolved_head_dim
    )

    cf, sf, zb, pb, ze, pe = [], [], [], [], [], []
    head_params = head_p if head_p else embed_p  # tied models still pay head FLOPs
    for tier in range(1, n_tiers + 1):
        s = bounds[tier - 1]
        c_active = _active_layer_params(cfg) * s
        s_active = _active_layer_params(cfg) * (cfg.n_layers - s)
        aux_p = cfg.d_model * cfg.vocab  # auxiliary local head
        cf.append(6.0 * (c_active + aux_p) * tokens + 3 * attn_flops * s / cfg.n_layers)
        sf.append(
            6.0 * (s_active + head_params) * tokens
            + 3 * attn_flops * (cfg.n_layers - s) / cfg.n_layers
        )
        ze.append(tokens * cfg.d_model)
        zb.append(tokens * cfg.d_model * 2 + tokens * 4)  # bf16 activations + labels
        pe.append(per_layer * s + embed_p)
        pb.append((per_layer * s + embed_p) * BYTES_PER_PARAM)
    from repro.models import model as Mm

    full_active = Mm.count_params_analytic(cfg, active_only=True)
    full_total = Mm.count_params_analytic(cfg)
    raw = np.array(cf, float)
    cf_adj = _with_client_overhead(raw)
    overhead = float(cf_adj[0] - raw[0])
    return TierCostTable(
        cf_adj, np.array(sf), np.array(zb), np.array(pb),
        full_flops=6.0 * full_active * tokens + 3 * attn_flops + overhead,
        full_param_bytes=full_total * BYTES_PER_PARAM,
        z_elems=np.array(ze, float), label_bytes=float(tokens * 4),
        param_elems=np.array(pe, float), full_param_elems=float(full_total),
    )


def _layer_params(cfg) -> int:
    from repro.models import model as M

    total = M.count_params_analytic(cfg)
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return max((total - embed) // cfg.n_layers, 1)


def _active_layer_params(cfg) -> int:
    from repro.models import model as M

    total = M.count_params_analytic(cfg, active_only=True)
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return max((total - embed) // cfg.n_layers, 1)


# ---------------------------------------------------------------------------
# round-time simulation (Eq. 5)
# ---------------------------------------------------------------------------

def simulate_client_times(
    costs: TierCostTable,
    tier: int,
    profile: ResourceProfile,
    n_batches: int,
    *,
    server_flops: float = SERVER_FLOPS,
    n_sharing: int = 1,
    wires=None,
    far_profile: ResourceProfile | None = None,
    link_bytes_per_s: float | None = None,
) -> dict:
    """Ground-truth times for one client & tier (0-based tier index).

    ``n_sharing``: how many clients' server-side models the (finite) server
    trains concurrently this round — its capacity is divided among them.
    ``wires``: a ``codec.WireSizes`` pricing the wires under a compression
    codec; None keeps the legacy identity accounting (same numbers).
    ``far_profile``: where the far half executes — None keeps the classic
    DTFL server (shared ``server_flops``); a peer ``ResourceProfile`` prices
    it at that device's full speed (pairing topology, core/topology.py).
    ``link_bytes_per_s``: per-link wire bandwidth override (peer↔peer links
    are bottlenecked by both ends); None uses the client's own uplink."""
    t_c = costs.client_flops[tier] * n_batches / profile.flops
    if wires is None:
        comm_bytes = costs.d_size(tier, n_batches) * n_batches
    else:
        comm_bytes = wires.z_bytes[tier] * n_batches + wires.param_bytes[tier]
    link = profile.bytes_per_s if link_bytes_per_s is None else link_bytes_per_s
    t_com = comm_bytes / link
    if far_profile is None:
        t_s = costs.server_flops[tier] * n_batches / (server_flops / max(n_sharing, 1))
    else:
        t_s = costs.server_flops[tier] * n_batches / far_profile.flops
    return {
        "client": t_c,
        "comm": t_com,
        "server": t_s,
        "total": max(t_c + t_com, t_s + t_com),  # Eq. (5)
    }


def rescale_remaining(
    total: float, elapsed: float,
    old: ResourceProfile, new: ResourceProfile,
) -> float:
    """New completion offset after a mid-round profile switch at ``elapsed``.

    The remaining round time is scaled by the compute-speed ratio: compute
    dominates the Eq.-5 total in the paper's regime, and the event layer
    deliberately does not track the compute/comm split of the *remaining*
    work. Used by the churn path of the event engine (fed/engine.py).
    """
    remaining = max(float(total) - float(elapsed), 0.0)
    return float(elapsed) + remaining * (old.flops / new.flops)


def simulate_client_times_batch(
    costs: TierCostTable,
    tiers: np.ndarray,
    flops: np.ndarray,
    bytes_per_s: np.ndarray,
    n_batches: np.ndarray,
    *,
    server_flops: float = SERVER_FLOPS,
    n_sharing: int = 1,
    wires=None,
    far_flops: np.ndarray | None = None,
    link_bytes_per_s: np.ndarray | None = None,
) -> dict:
    """Vectorized :func:`simulate_client_times` over a round's participants.

    All array arguments are per-client; returns a dict of per-client arrays
    with the exact same formulas (so scheduler observations are identical to
    the scalar path). ``wires`` prices the wires under a compression codec
    (``codec.WireSizes``); None keeps the legacy identity accounting.
    ``far_flops``: per-client effective speed of whatever executes the far
    half (already divided by any sharing) — None keeps the classic shared
    server. ``link_bytes_per_s``: per-client effective wire bandwidth
    (peer links are bottlenecked by both ends) — None uses each client's
    own uplink."""
    tiers = np.asarray(tiers, int)
    nb = np.asarray(n_batches, float)
    if wires is None:
        comm_bytes = (costs.z_bytes[tiers] * nb
                      + costs.client_param_bytes[tiers])
    else:
        comm_bytes = wires.z_bytes[tiers] * nb + wires.param_bytes[tiers]
    t_c = costs.client_flops[tiers] * nb / np.asarray(flops, float)
    link = bytes_per_s if link_bytes_per_s is None else link_bytes_per_s
    t_com = comm_bytes / np.asarray(link, float)
    if far_flops is None:
        t_s = costs.server_flops[tiers] * nb / (server_flops / max(n_sharing, 1))
    else:
        t_s = costs.server_flops[tiers] * nb / np.asarray(far_flops, float)
    return {
        "client": t_c,
        "comm": t_com,
        "server": t_s,
        "total": np.maximum(t_c + t_com, t_s + t_com),
    }
