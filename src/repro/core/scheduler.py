"""Dynamic tier scheduler — Algorithm 1 of the paper.

Host-side (numpy) component. The scheduler sees ONLY what the paper's server
sees per round:
  * the measured total client-side time of each client in its assigned tier,
  * the client's communicated link speed ``nu`` (bytes/s),
  * the client's batch count ``n_batches``.

Tier profiling (done once, lines "Tier Profiling"): reference per-tier
client/server times ``t_client_ref[m]``, ``t_server_ref[m]`` on a standard
batch, and transfer sizes — per-batch uplink ``z_bytes[m]`` plus the
per-round parameter wire ``param_bytes[m]``, kept separate so per-client
communication composes as ``z_bytes*N_k + param_bytes`` for any task size
``N_k`` (folding them into one per-batch ``d_size`` baked a reference batch
count into the profile and overcounted the download by ``N_k/N_ref`` for
clients whose task size differs). The Table-2 invariance — normalized
time ratios between tiers are client-independent — lets the scheduler
extrapolate a client's time in *unobserved* tiers from the one observed tier
(Algorithm 1 lines 24-29).

Scheduling (lines 31-33):
  T_max  = max_k min_m  T_hat_k(m)
  m_k    = argmax_m { m : T_hat_k(m) <= T_max }   (least offloading)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class TierProfile:
    """Server-side profiling table (per standard batch).

    Communication is profiled per wire: ``z_bytes`` scales with a client's
    batch count, ``param_bytes`` is paid once per round. Legacy callers may
    still pass a combined per-batch ``d_size``; it is treated as all-z
    (every byte scales with n_batches), which reproduces the old
    ``d_size * N / nu`` composition exactly.
    """

    t_client_ref: np.ndarray   # (M,) reference client compute time per batch
    t_server_ref: np.ndarray   # (M,) server compute time per batch
    d_size: np.ndarray | None = None       # legacy: combined bytes per batch
    z_bytes: np.ndarray | None = None      # (M,) per-batch uplink bytes
    param_bytes: np.ndarray | None = None  # (M,) per-round parameter bytes
    server_speedup: float | None = None    # server flops / reference-client flops

    def __post_init__(self):
        if self.server_speedup is None:
            from repro.core.timemodel import SERVER_FLOPS, UNIT_FLOPS

            self.server_speedup = SERVER_FLOPS / UNIT_FLOPS
        self.server_speedup = float(self.server_speedup)
        if self.z_bytes is None:
            if self.d_size is None:
                raise ValueError("TierProfile needs z_bytes (+param_bytes) "
                                 "or a legacy d_size")
            self.z_bytes = np.asarray(self.d_size, float)
        else:
            self.z_bytes = np.asarray(self.z_bytes, float)
        if self.param_bytes is None:
            self.param_bytes = np.zeros_like(self.z_bytes)
        else:
            self.param_bytes = np.asarray(self.param_bytes, float)

    @property
    def n_tiers(self) -> int:
        return len(self.t_client_ref)

    def comm_bytes(self, tiers, n_batches):
        """Per-round wire bytes for clients at ``tiers`` with ``n_batches``
        local batches (the D^m*N term of Algorithm 1 line 22, per-wire)."""
        return (self.z_bytes[tiers] * np.asarray(n_batches, float)
                + self.param_bytes[tiers])

    @classmethod
    def from_cost_table(cls, costs, *, ref_flops: float, server_flops: float,
                        wires=None):
        """Build the profile from an analytic TierCostTable (timemodel.py).

        ``wires`` (a ``codec.WireSizes``) prices the wires under the active
        compression codec; None uses the identity accounting. The profile
        keeps z and parameter bytes separate — the old version baked a
        reference ``n_batches`` into one d_size, which overcounted the
        parameter wire for clients with a different task size.
        """
        from repro.core.codec import wire_sizes

        w = wires if wires is not None else wire_sizes(costs)
        return cls(
            t_client_ref=costs.client_flops / ref_flops,
            t_server_ref=costs.server_flops / server_flops,
            z_bytes=np.asarray(w.z_bytes, float).copy(),
            param_bytes=np.asarray(w.param_bytes, float).copy(),
            server_speedup=server_flops / ref_flops,
        )


class EMA:
    def __init__(self, alpha: float = 0.5):
        self.alpha = alpha
        self.value: float | None = None

    def update(self, x: float) -> float:
        self.value = x if self.value is None else self.alpha * x + (1 - self.alpha) * self.value
        return self.value


@dataclass
class _ClientState:
    tier: int                      # currently assigned tier (0-based)
    nu: float = 1e6                # last communicated link bytes/s
    n_batches: int = 1
    ema: dict = field(default_factory=dict)   # tier -> EMA of client compute time
    last_obs_tier: int | None = None


class _LazyClientStates:
    """Per-client scheduler state, materialized on first access.

    Looks like the dense ``list[_ClientState]`` it replaced (``len``, ``[]``,
    iteration — tests and small-n callers iterate it), but a never-observed
    client allocates no state until someone touches it, so a million-client
    registry costs O(sampled participants), not O(population). Iteration
    materializes everything and is reserved for test-sized registries.
    """

    def __init__(self, n: int, init_tier: int):
        self._n = int(n)
        self._init_tier = int(init_tier)
        self._states: dict[int, _ClientState] = {}

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, k: int) -> _ClientState:
        k = int(k)
        if not 0 <= k < self._n:
            raise IndexError(f"client id {k} out of range [0, {self._n})")
        st = self._states.get(k)
        if st is None:
            st = self._states[k] = _ClientState(tier=self._init_tier)
        return st

    def __iter__(self):
        for k in range(self._n):
            yield self[k]

    @property
    def n_touched(self) -> int:
        return len(self._states)

    def touched(self) -> list[int]:
        return sorted(self._states)

    def touched_items(self) -> list[tuple[int, _ClientState]]:
        return sorted(self._states.items())

    def is_touched(self, k: int) -> bool:
        return int(k) in self._states

    def compact(self, keep) -> None:
        keep = set(int(k) for k in keep)
        self._states = {k: v for k, v in self._states.items() if k in keep}


class DynamicTierScheduler:
    """Stateful per-round scheduler. Tiers are 0-based here (paper: 1-based).

    The estimate matrix is INCREMENTAL: each client's T_hat row is cached
    and only recomputed after a new observation lands for that client (or
    for a never-observed client, served from one shared default row), so a
    round's scheduling costs O(observed-this-round + participants), never
    O(population). ``_row_recomputes`` counts row rebuilds — the
    regression test pins that it tracks observations, not registry size.
    """

    def __init__(self, profile: TierProfile, n_clients: int, *, ema_alpha: float = 0.5,
                 init_tier: int | None = None, allowed: list[int] | None = None):
        self.profile = profile
        self.M = profile.n_tiers
        # Table 11: an M-tier deployment exposes the LAST M split options
        # (the full-client option always exists; more tiers add offloading)
        self.allowed = sorted(allowed) if allowed is not None else list(range(self.M))
        init_tier = self.allowed[-1] if init_tier is None else init_tier
        self.clients = _LazyClientStates(n_clients, init_tier)
        self._rows: dict[int, np.ndarray] = {}   # cid -> cached T_hat row
        self._default_row: np.ndarray | None = None
        self._row_recomputes = 0

    # ------------------------------------------------------------------
    # Algorithm 1, lines 21-23: measure & update histories
    # ------------------------------------------------------------------
    def observe(self, k: int, *, tier: int, total_client_time: float, nu: float,
                n_batches: int) -> None:
        """Record a round observation for client k.

        ``total_client_time`` includes communication (as measured by a real
        server); the compute part is recovered as T - D^m * N / nu (line 22).
        """
        st = self.clients[k]
        st.nu = nu
        st.n_batches = n_batches
        comm = self.profile.comm_bytes(tier, n_batches) / nu
        compute = max(total_client_time - comm, 1e-9)
        st.ema.setdefault(tier, EMA()).update(compute)
        st.last_obs_tier = tier
        st.tier = tier
        self._rows.pop(k, None)    # row depends on (nu, nb, ema): recompute lazily

    def observe_cohort(self, ks, tiers, total_client_times, nus, n_batches) -> None:
        """Vectorized :meth:`observe` for a whole round's participants.

        The compute-time recovery (line 22) is done as one array expression;
        per-client EMA state updates follow. Results are identical to calling
        ``observe`` per client."""
        tiers = np.asarray(tiers, int)
        nb = np.asarray(n_batches)
        comm = self.profile.comm_bytes(tiers, nb) / np.asarray(nus, float)
        compute = np.maximum(np.asarray(total_client_times, float) - comm, 1e-9)
        for k, tier, c, nu, n in zip(ks, tiers, compute, nus, nb):
            st = self.clients[k]
            st.nu = float(nu)
            st.n_batches = int(n)
            st.ema.setdefault(int(tier), EMA()).update(float(c))
            st.last_obs_tier = int(tier)
            st.tier = int(tier)
            self._rows.pop(int(k), None)

    # ------------------------------------------------------------------
    # Algorithm 1, lines 24-29: per-tier estimates
    # ------------------------------------------------------------------
    def _state_row(self, nu: float, nb: float, last_obs_tier, ema_value) -> np.ndarray:
        """One client's T_hat row (Eq. 5 composition). Same elementwise IEEE
        expressions as the old dense (K, M) rebuild, so cached rows are
        bit-identical to a from-scratch recompute."""
        prof = self.profile
        t_com = (prof.z_bytes * nb + prof.param_bytes) / nu                   # (M,)
        t_srv = prof.t_server_ref * nb                                        # (M,)
        if last_obs_tier is None:
            t_cli = prof.t_client_ref * nb                                    # no-obs fallback
        else:
            m0 = last_obs_tier
            t_cli = prof.t_client_ref / prof.t_client_ref[m0] * ema_value     # EMA'd round time
        return np.maximum(t_cli + t_com, t_srv + t_com)

    def _row(self, k: int) -> np.ndarray:
        """Cached T_hat row for client ``k``; recomputed only after a new
        observation invalidated it. Never-observed clients share ONE default
        row (their state is uniform), so they cost no per-client work."""
        k = int(k)
        row = self._rows.get(k)
        if row is not None:
            return row
        if not self.clients.is_touched(k):
            if self._default_row is None:
                d = _ClientState(tier=0)    # tier does not enter the row
                self._default_row = self._state_row(
                    float(d.nu), float(d.n_batches), None, None)
                self._row_recomputes += 1
            return self._default_row
        st = self.clients[k]
        m0 = st.last_obs_tier
        row = self._state_row(
            float(st.nu), float(st.n_batches), m0,
            st.ema[m0].value if m0 is not None else None)
        self._rows[k] = row
        self._row_recomputes += 1
        return row

    def estimate_matrix(self, ks: list[int]) -> np.ndarray:
        """T_hat_k(m) for every k in ``ks`` and every m, as a (K, M) matrix
        (Eq. 5 composition). Assembled from per-client cached rows — cost is
        O(rows invalidated since the last call), not O(population)."""
        return np.stack([self._row(k) for k in ks])

    def estimate(self, k: int) -> np.ndarray:
        """T_hat_k(m) for all m (Eq. 5 composition)."""
        return self.estimate_matrix([k])[0]

    # ------------------------------------------------------------------
    # Algorithm 1, lines 31-33: assignment
    # ------------------------------------------------------------------
    def schedule(self, participants: list[int] | None = None) -> dict[int, int]:
        ks = list(range(len(self.clients))) if participants is None else list(participants)
        sel = np.array(self.allowed)
        est = self.estimate_matrix(ks)[:, sel]                                # (K, |sel|)
        t_max = est.min(axis=1).max()                                         # line 31
        feasible = est <= t_max + 1e-12
        assign = {}
        for i, k in enumerate(ks):                                            # line 33
            ok = np.flatnonzero(feasible[i])
            m = int(sel[ok.max()]) if len(ok) else int(sel[est[i].argmin()])
            assign[k] = m
            self.clients[k].tier = m
        return assign

    def round_time(self, assign: dict[int, int]) -> float:
        """Estimated straggler time under an assignment."""
        return max(self.estimate(k)[m] for k, m in assign.items())

    def compact(self, keep) -> None:
        """Drop per-client state/rows of clients outside ``keep`` (permanent
        departures); a compacted client that returns restarts from the
        default (never-observed) state."""
        self.clients.compact(keep)
        keep = set(int(k) for k in keep)
        self._rows = {k: v for k, v in self._rows.items() if k in keep}


class StaticScheduler:
    """Ablation: fixed tier for everyone (the paper's Table 1 columns)."""

    def __init__(self, tier: int, n_clients: int):
        self.tier = tier
        self.n = n_clients

    def observe(self, *a, **kw):
        pass

    def observe_cohort(self, *a, **kw):
        pass

    def schedule(self, participants=None) -> dict[int, int]:
        ks = range(self.n) if participants is None else participants
        return {k: self.tier for k in ks}


# ---------------------------------------------------------------------------
# Pairing / mutual-offload scheduling (arxiv 2308.13849)
# ---------------------------------------------------------------------------

def _greedy_pairs(C: np.ndarray) -> list[tuple[int, int]]:
    """Slowest-guest-first greedy matching on a square cost matrix."""
    n = C.shape[0]
    order = np.argsort(-C.min(axis=1), kind="stable")   # most expensive first
    taken: set[int] = set()
    pairs = []
    for gi in order:
        free = [h for h in range(n) if h not in taken]
        hi = min(free, key=lambda h: C[gi, h])
        taken.add(hi)
        pairs.append((int(gi), int(hi)))
    return sorted(pairs)


def _hungarian_pairs(C: np.ndarray) -> list[tuple[int, int]]:
    """Minimum-total-cost perfect matching. Uses scipy's Jonker-Volgenant
    solver when available; otherwise exact enumeration for small instances
    and the greedy matching beyond (documented approximation)."""
    n = C.shape[0]
    try:
        from scipy.optimize import linear_sum_assignment
    except ImportError:
        if n <= 8:
            import itertools

            best, best_cost = None, np.inf
            for perm in itertools.permutations(range(n)):
                cost = sum(C[i, j] for i, j in enumerate(perm))
                if cost < best_cost:
                    best, best_cost = perm, cost
            return [(i, int(j)) for i, j in enumerate(best)]
        return _greedy_pairs(C)
    rows, cols = linear_sum_assignment(C)
    return sorted(zip(rows.tolist(), cols.tolist()))


class PairingScheduler(DynamicTierScheduler):
    """Mutual-offload tiers: fast clients host slow clients' far halves.

    Extends Algorithm 1 with the pairing idea of "Effectively Heterogeneous
    Federated Learning: A Pairing and Split Learning Based Approach" (arxiv
    2308.13849): after the baseline DTFL tier assignment, the observed-fast
    half of the cohort is offered as hosts and the observed-slow half as
    guests, and a minimum-cost perfect matching (greedy or Hungarian) over
    the pair-cost matrix decides who offloads to whom.  Unmatched and
    homogeneous cohorts fall back to the classic all-server schedule, so the
    first rounds (no observations yet) are identical to DTFL.

    ``schedule()`` returns the generalized assignment ``cid ->
    Assignment(tier, host)`` (core/topology.py); ``host == SERVER`` is the
    classic case.  Everything the scheduler uses is observable server-side:
    EMA'd client compute times, communicated link speeds ``nu``, and the
    profiling table (extended with ``server_speedup`` so a far half can be
    priced on a *client* profile).
    """

    provides_hosts = True

    def __init__(self, profile: TierProfile, n_clients: int, *,
                 method: str = "hungarian", ema_alpha: float = 0.5,
                 init_tier: int | None = None, allowed: list[int] | None = None,
                 min_spread: float = 1.5):
        if method not in ("hungarian", "greedy"):
            raise ValueError(f"pairing method {method!r} not in "
                             "('hungarian', 'greedy')")
        super().__init__(profile, n_clients, ema_alpha=ema_alpha,
                         init_tier=init_tier, allowed=allowed)
        self.method = method
        self.min_spread = float(min_spread)
        self.last_hosts: dict[int, int] = {}   # guest cid -> host cid

    # ---- observed relative compute speed (1.0 = profiling reference) ----
    def speed(self, k: int) -> float | None:
        if not self.clients.is_touched(k):
            return None
        st = self.clients[k]
        if st.last_obs_tier is None:
            return None
        m0 = st.last_obs_tier
        ref = self.profile.t_client_ref[m0] * st.n_batches
        return float(ref / max(st.ema[m0].value, 1e-12))

    def _pair_costs(self, guests: list[int], hosts: list[int],
                    base: dict[int, int]) -> tuple[np.ndarray, np.ndarray]:
        """Pair-cost matrix C[g, h] = best-tier completion time of the pair,
        and T[g, h] = that minimizing tier.

        Per tier m: the guest computes its near half (EMA-extrapolated, the
        Table-2 invariance), the wire is the bottleneck of the two ends'
        links, the far half runs at the host's observed speed
        (``t_server_ref * server_speedup / speed_host``), and hosting is
        serialized after the host's own round."""
        prof = self.profile
        sel = np.array(self.allowed)
        C = np.full((len(guests), len(hosts)), np.inf)
        T = np.zeros((len(guests), len(hosts)), int)
        for i, g in enumerate(guests):
            st_g = self.clients[g]
            m0 = st_g.last_obs_tier
            nb = float(st_g.n_batches)
            t_cli = (prof.t_client_ref / prof.t_client_ref[m0]
                     * st_g.ema[m0].value)[sel]
            for j, h in enumerate(hosts):
                st_h = self.clients[h]
                link = min(st_g.nu, st_h.nu)
                t_com = (prof.z_bytes[sel] * nb + prof.param_bytes[sel]) / link
                t_far = (prof.t_server_ref[sel] * prof.server_speedup * nb
                         / self.speed(h))
                host_busy = self._row(h)[base[h]]
                pair = np.maximum(t_cli + t_com,
                                  np.maximum(t_far + t_com, host_busy + t_far))
                m = int(pair.argmin())
                C[i, j] = float(pair[m])
                T[i, j] = int(sel[m])
        return C, T

    def schedule(self, participants: list[int] | None = None) -> dict:
        from repro.core.topology import SERVER, Assignment

        ks = (list(range(len(self.clients))) if participants is None
              else list(participants))
        base = super().schedule(ks)                      # Algorithm 1 tiers
        out = {k: Assignment(base[k], SERVER) for k in ks}
        self.last_hosts = {}

        speeds = {k: self.speed(k) for k in ks}
        known = [k for k in ks if speeds[k] is not None]
        if len(known) >= 2:
            vals = np.array([speeds[k] for k in known])
            spread_ok = vals.max() >= self.min_spread * vals.min()
        else:
            spread_ok = False
        if not spread_ok:
            return out                                    # server fallback

        # fast half hosts, slow half guests; odd middle stays on the server
        order = sorted(known, key=lambda k: (-speeds[k], k))
        n_pairs = len(order) // 2
        if n_pairs == 0:
            return out
        hosts = order[:n_pairs]
        guests = order[-n_pairs:]
        C, T = self._pair_costs(guests, hosts, base)
        pairs = (_greedy_pairs(C) if self.method == "greedy"
                 else _hungarian_pairs(C))

        # accept a pair only if it does not worsen the projected straggler
        t_round = max(float(self._row(k)[base[k]]) for k in ks)
        for gi, hi in pairs:
            g, h = guests[gi], hosts[hi]
            if C[gi, hi] <= t_round:
                out[g] = Assignment(int(T[gi, hi]), h)
                self.clients[g].tier = int(T[gi, hi])
                self.last_hosts[g] = h
        return out
