"""Offload topology: who executes each client's far half, and at what price.

DTFL (PAPER.md §3) hardcodes "the far half runs on *the server*".  The
pairing literature (arxiv 2308.13849) shows fast clients can instead host
slow clients' far-halves, with the activation/update wires priced per-link
(FedDCT, arxiv 2307.04420).  This module is the host-agnostic layer between
the schedulers and the time model:

* :class:`Assignment` — one client's generalized schedule entry
  ``(tier, host)``; ``host == SERVER`` (-1) is the classic DTFL case,
  ``host == cid`` of a peer means that peer executes the far half.
* :class:`OffloadTopology` — a round's full ``cid -> Assignment`` map, plus
  the engine-side widening adapter :meth:`OffloadTopology.from_schedule`
  that accepts the narrow ``cid -> tier`` dicts the static/dynamic
  schedulers return, so baselines that ignore hosts keep working without
  per-trainer shims.
* :func:`simulate_times` — per-link Eq. 5 pricing under an arbitrary
  topology.  For a server-only topology it reduces exactly to
  ``timemodel.simulate_client_times_batch`` with the legacy arguments
  (equivalence-tested), so ``topology=server`` stays bit-for-bit identical.

Only scheduling and time/byte accounting change with the topology.  The
training math (cohort programs, aux heads, aggregation) is keyed by tier
alone — *where* the far half runs is a simulation-plane distinction, exactly
like client ``ResourceProfile``s.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping, NamedTuple, Sequence

import numpy as np

from repro.core import timemodel

SERVER = -1  # host id of the central server


class Assignment(NamedTuple):
    """Generalized schedule entry for one client: ``(tier, host)``."""

    tier: int
    host: int = SERVER


def as_assignment(value) -> Assignment:
    """Widen a scheduler output value: bare tier int or ``(tier, host)``."""
    if isinstance(value, Assignment):
        return value
    if isinstance(value, tuple):
        tier, host = value
        return Assignment(int(tier), int(host))
    return Assignment(int(value), SERVER)


@dataclass(frozen=True)
class OffloadTopology:
    """A round's full offload map: ``cid -> Assignment``."""

    assign: Mapping[int, Assignment]

    @classmethod
    def from_schedule(cls, schedule: Mapping[int, object]) -> "OffloadTopology":
        """Engine-side adapter over ``scheduler.schedule()`` output.

        Accepts the narrow ``cid -> tier`` dict (StaticScheduler,
        DynamicTierScheduler) and the generalized ``cid -> (tier, host)``
        dict (PairingScheduler) alike.
        """
        return cls({int(k): as_assignment(v) for k, v in schedule.items()})

    def tiers(self) -> dict[int, int]:
        """The narrow view every existing consumer (cohorts, EF, logs) uses."""
        return {k: a.tier for k, a in self.assign.items()}

    def hosts(self) -> dict[int, int]:
        return {k: a.host for k, a in self.assign.items()}

    @property
    def is_server_only(self) -> bool:
        return all(a.host == SERVER for a in self.assign.values())

    def server_hosted(self) -> list[int]:
        return [k for k, a in self.assign.items() if a.host == SERVER]

    def guests_of(self) -> dict[int, list[int]]:
        """host cid -> guests whose far half it executes."""
        out: dict[int, list[int]] = {}
        for k, a in self.assign.items():
            if a.host != SERVER:
                out.setdefault(a.host, []).append(k)
        return out


def simulate_times(costs, topo: OffloadTopology, participants: Sequence[int],
                   profiles: Iterable[timemodel.ResourceProfile],
                   n_batches: np.ndarray, *,
                   server_flops: float = timemodel.SERVER_FLOPS,
                   wires=None) -> dict[str, np.ndarray]:
    """Per-link Eq. 5 round times under a general offload topology.

    Pricing model:

    * server-hosted clients share ``server_flops`` equally — but only among
      themselves (``n_sharing`` = number of server-hosted participants, the
      capacity relief pairing buys);
    * a peer-hosted far half runs at the host's full device speed, and its
      wire is the bottleneck of the two ends' bandwidths;
    * a host's own round is extended by the far-half work it executes for
      its guests (hosting is serialized with the host's own training).
    """
    parts = list(participants)
    pos = {k: i for i, k in enumerate(parts)}
    tiers = np.array([topo.assign[k].tier for k in parts])
    hosts = [topo.assign[k].host for k in parts]
    flops = np.array([p.flops for p in profiles])
    bps = np.array([p.bytes_per_s for p in profiles])
    nb = np.asarray(n_batches)

    n_srv = max(sum(1 for h in hosts if h == SERVER), 1)
    far_flops = np.empty(len(parts))
    link = bps.copy()
    for i, h in enumerate(hosts):
        if h == SERVER:
            far_flops[i] = server_flops / n_srv
        else:
            far_flops[i] = flops[pos[h]]
            link[i] = min(bps[i], bps[pos[h]])

    t = timemodel.simulate_client_times_batch(
        costs, tiers, flops, bps, nb, server_flops=server_flops,
        wires=wires, far_flops=far_flops, link_bytes_per_s=link)

    # hosting extends the host's round by its guests' far-half work
    hosting = np.zeros(len(parts))
    for i, h in enumerate(hosts):
        if h != SERVER:
            hosting[pos[h]] += t["server"][i]
    t["total"] = t["total"] + hosting
    t["link"] = link
    return t
