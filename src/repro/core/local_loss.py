"""Local-loss-based split training (the paper's §3.2 / Algorithm 1 steps 2-4).

Client and server updates are *decoupled*: the client trains
(client-side blocks + auxiliary head) against a local loss; the server trains
the server-side blocks + task head on ``stop_gradient(z)``. No gradient ever
crosses the split, so both halves advance in parallel — the property the
dynamic tier scheduler's time model (Eq. 5: max of the two paths) relies on.

``make_dtfl_train_step`` builds the per-tier jitted step. Tier (= split
point) is static, so a DTFL deployment holds <= M compiled executables.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import OptState, Optimizer

Params = dict
MOE_AUX_WEIGHT = 0.01


def token_xent(logits: jax.Array, labels: jax.Array,
               weight: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy; logits any float dtype, stats in fp32.

    ``weight`` (leading-axes-broadcastable, e.g. a per-sample (B,) pad mask
    from ``data/pipeline.py``) turns the mean into a weighted mean so padded
    samples contribute nothing."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    per = lse - picked
    if weight is None:
        return jnp.mean(per)
    w = weight.astype(jnp.float32)
    w = jnp.broadcast_to(w.reshape(w.shape + (1,) * (per.ndim - w.ndim)), per.shape)
    return jnp.sum(per * w) / jnp.maximum(jnp.sum(w), 1.0)


class DTFLState(NamedTuple):
    client_params: Params
    aux_params: Params
    server_params: Params
    client_opt: OptState
    aux_opt: OptState
    server_opt: OptState


class DTFLMetrics(NamedTuple):
    client_loss: jax.Array
    server_loss: jax.Array


def init_tier_state(key, cfg, params: Params, tier: int, optimizer: Optimizer) -> DTFLState:
    from repro.core import tiering

    client_p, server_p = tiering.split_params(params, cfg, tier)
    aux_p = M.aux_head_init(key, cfg)
    return DTFLState(
        client_params=client_p,
        aux_params=aux_p,
        server_params=server_p,
        client_opt=optimizer.init(client_p),
        aux_opt=optimizer.init(aux_p),
        server_opt=optimizer.init(server_p),
    )


def make_dtfl_train_step(
    cfg,
    optimizer: Optimizer,
    *,
    dcor_alpha: float = 0.0,
    dcor_fn: Callable | None = None,
) -> Callable:
    """Returns step(state, batch) -> (state, DTFLMetrics).

    ``dcor_alpha`` > 0 enables the §4.4 privacy regularizer
    ``(1-a)·loss + a·DCor(x, z)`` on the client objective.
    """

    def step(state: DTFLState, batch: dict) -> tuple[DTFLState, DTFLMetrics]:
        labels = batch["labels"]

        # ---- client: local loss through the auxiliary head ----
        def client_loss(cp, ap):
            z, moe_aux = M.client_forward(cp, cfg, batch)
            logits = M.aux_head_apply(ap, cfg, z)
            loss = token_xent(logits, labels) + MOE_AUX_WEIGHT * moe_aux
            if dcor_alpha > 0.0:
                x_in = M.embed_tokens(cp, cfg, batch)
                zz = z[0] if isinstance(z, tuple) else z
                loss = (1.0 - dcor_alpha) * loss + dcor_alpha * dcor_fn(x_in, zz)
            return loss, z

        (closs, z), (cgrads, agrads) = jax.value_and_grad(
            client_loss, argnums=(0, 1), has_aux=True
        )(state.client_params, state.aux_params)

        # ---- server: task loss on detached activations (parallel path) ----
        z = jax.lax.stop_gradient(z)

        def server_loss(sp):
            logits, moe_aux = M.server_forward(sp, cfg, z)
            return token_xent(logits, labels) + MOE_AUX_WEIGHT * moe_aux

        sloss, sgrads = jax.value_and_grad(server_loss)(state.server_params)

        cp, copt = optimizer.update(state.client_params, cgrads, state.client_opt)
        ap, aopt = optimizer.update(state.aux_params, agrads, state.aux_opt)
        sp, sopt = optimizer.update(state.server_params, sgrads, state.server_opt)
        return (
            DTFLState(cp, ap, sp, copt, aopt, sopt),
            DTFLMetrics(client_loss=closs, server_loss=sloss),
        )

    return step


# ---------------------------------------------------------------------------
# monolithic step (FedAvg-style baselines / dry-run reference)
# ---------------------------------------------------------------------------

def make_full_train_step(cfg, optimizer: Optimizer) -> Callable:
    """Conventional single-loss step over the unsplit model."""

    def step(params: Params, opt_state: OptState, batch: dict):
        def loss_fn(p):
            logits, moe_aux = M.forward(p, cfg, batch)
            return token_xent(logits, batch["labels"]) + MOE_AUX_WEIGHT * moe_aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, loss

    return step
