"""Arch-agnostic parameter-tree splitting at an arbitrary boundary.

This is the single home for near-half / far-half parameter splitting.  A
:class:`SplitScheme` describes, per architecture, how a full parameter tree
decomposes into

* a run of repeated blocks under ``blocks_key`` that an integer boundary
  slices into a near (input-adjacent) and a far (output-adjacent) run, and
* fixed keys that always travel with one half (``near_keys`` input-adjacent,
  ``far_keys`` head-side).

Two layouts exist in this repo: the transformer stacks block parameters on a
leading layer axis (``stacked=True`` — the slice is a tree ``a[:b]``), while
the ResNet keeps a Python list of per-block trees (``stacked=False`` — the
slice is a list slice).  Both directions are lossless: ``merge_params``
inverts ``split_params`` exactly, which is what makes cross-tier FedAvg
aggregation exact.

Policy (which boundary a tier maps to) stays with the callers:
``core/tiering.py`` owns the paper's module→boundary table for transformers,
``models/resnet.py`` owns ``n_blocks_in_modules`` for the ResNet; both route
their mechanics through here.  The offload *topology* (who executes the far
half — server or a paired peer) is orthogonal and lives in
``core/topology.py``; the trees produced here are host-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

Params = dict


@dataclass(frozen=True)
class SplitScheme:
    """How one architecture's parameter tree splits at a block boundary."""

    stacked: bool                 # blocks on a leading layer axis vs a list
    near_keys: tuple[str, ...]    # always input-side (client/guest)
    far_keys: tuple[str, ...]     # always head-side (server/host)
    blocks_key: str = "blocks"


# The transformer stacks per-layer params (scan-style); embed/projection and
# final-norm/head bookend the block run.
TRANSFORMER = SplitScheme(
    stacked=True,
    near_keys=("embed", "front_proj", "enc_blocks", "enc_ln"),
    far_keys=("final_ln", "lm_head"),
)

# The ResNet keeps a list of per-block trees; the stem is input-side, the
# classifier head is far-side.
RESNET = SplitScheme(stacked=False, near_keys=("stem",), far_keys=("fc",))


def split_params(params: Params, boundary: int,
                 scheme: SplitScheme) -> tuple[Params, Params]:
    """Split ``params`` so the near half keeps blocks ``[:boundary]``.

    Returns ``(near, far)``; fixed keys are copied to their scheme-assigned
    half (skipped when absent, e.g. cost-model-only trees).
    """
    blocks = params[scheme.blocks_key]
    if scheme.stacked:
        near: Params = {scheme.blocks_key: jax.tree.map(lambda a: a[:boundary], blocks)}
        far: Params = {scheme.blocks_key: jax.tree.map(lambda a: a[boundary:], blocks)}
    else:
        near = {scheme.blocks_key: blocks[:boundary]}
        far = {scheme.blocks_key: blocks[boundary:]}
    for k in scheme.near_keys:
        if k in params:
            near[k] = params[k]
    for k in scheme.far_keys:
        if k in params:
            far[k] = params[k]
    return near, far


def merge_params(near: Params, far: Params, scheme: SplitScheme) -> Params:
    """Inverse of :func:`split_params` — lossless for any boundary."""
    if scheme.stacked:
        blocks = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              near[scheme.blocks_key], far[scheme.blocks_key])
    else:
        blocks = list(near[scheme.blocks_key]) + list(far[scheme.blocks_key])
    merged: Params = {scheme.blocks_key: blocks}
    for k in scheme.near_keys:
        if k in near:
            merged[k] = near[k]
    for k in scheme.far_keys:
        if k in far:
            merged[k] = far[k]
    return merged
