"""FedAvg aggregation across tiers (Algorithm 1 lines 11-13, Appendix A.7 (5)).

Each client's (client-side, server-side) halves are merged back into a full
parameter tree (lossless — tiering.merge_params), then averaged with weights
``N_k / N`` (Eq. 1; Algorithm 1 line 13 uses 1/K — we expose both).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict


def weighted_average(trees: list[Params], weights: list[float]) -> Params:
    w = jnp.asarray(weights, jnp.float32)
    w = w / w.sum()

    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        out = jnp.tensordot(w, stacked, axes=1)
        return out.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)


def uniform_average(trees: list[Params]) -> Params:
    return weighted_average(trees, [1.0] * len(trees))


def aggregate_dtfl_round(cfg, tier_states: list[tuple[int, Params, Params]],
                         weights: list[float]) -> Params:
    """tier_states: [(tier, client_params, server_params)] per client."""
    from repro.core import tiering

    fulls = [tiering.merge_params(c, s) for _, c, s in tier_states]
    return weighted_average(fulls, weights)
