"""FedAvg aggregation across tiers (Algorithm 1 lines 11-13, Appendix A.7 (5)).

Each client's (client-side, server-side) halves are merged back into a full
parameter tree (lossless — tiering.merge_params), then averaged with weights
``N_k / N`` (Eq. 1; Algorithm 1 line 13 uses 1/K — we expose both).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict


def weighted_average(trees: list[Params], weights: list[float]) -> Params:
    w = jnp.asarray(weights, jnp.float32)
    w = w / w.sum()

    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        out = jnp.tensordot(w, stacked, axes=1)
        return out.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)


def uniform_average(trees: list[Params]) -> Params:
    return weighted_average(trees, [1.0] * len(trees))


@jax.jit
def _wavg_cohorts(stacked_trees: list, ws: list):
    total = sum(w.sum() for w in ws)

    def partial(w):
        return lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=1)

    acc = jax.tree.map(partial(ws[0]), stacked_trees[0])
    for tree, w in zip(stacked_trees[1:], ws[1:]):
        acc = jax.tree.map(lambda a, x, p=partial(w): a + p(x), acc, tree)
    return jax.tree.map(
        lambda a, x: (a / total).astype(x.dtype), acc, stacked_trees[0]
    )


def weighted_average_cohorts(stacked_trees: list[Params], weights: list) -> Params:
    """Weighted average across several stacked pytrees (one per cohort).

    Every tree carries a leading client axis; weights are per-client within
    each cohort and normalized over the union of all cohorts. Runs as one
    jitted program (cached per pytree structure/shapes)."""
    ws = [jnp.asarray(w, jnp.float32) for w in weights]
    return _wavg_cohorts(stacked_trees, ws)


@jax.jit
def _combine_sums(sums: list, totals: list, like: Params):
    total = totals[0]
    for t in totals[1:]:
        total = total + t
    acc = sums[0]
    for s in sums[1:]:
        acc = jax.tree.map(lambda a, x: a + x, acc, s)
    return jax.tree.map(lambda a, p: (a / total).astype(p.dtype), acc, like)


def combine_weighted_sums(sums: list[Params], totals: list, like: Params) -> Params:
    """Finalize per-cohort weighted SUMS into the global weighted average.

    The sharded plane's cohort programs reduce their client axis on-device
    (``psum`` of ``tensordot(w, x)`` partials + ``psum`` of ``w.sum()``); the
    host only ever sees one (sum_tree, weight_total) pair per cohort. This
    mirrors ``_wavg_cohorts`` exactly — same per-cohort partials, same
    cohort-order accumulation, same single division — so a 1-shard mesh
    reproduces the cohort plane bit-for-bit. ``like`` supplies output dtypes.
    """
    totals = [jnp.asarray(t, jnp.float32) for t in totals]
    return _combine_sums(sums, totals, like)


def aggregate_dtfl_round(cfg, tier_states: list[tuple[int, Params, Params]],
                         weights: list[float]) -> Params:
    """tier_states: [(tier, client_params, server_params)] per client."""
    from repro.core import tiering

    fulls = [tiering.merge_params(c, s) for _, c, s in tier_states]
    return weighted_average(fulls, weights)
