"""Serving entry point: batched greedy decoding with a KV cache.

DTFL's split-offloading applies to inference as well: with --split-tier the
client-side prefix runs "on device" and the server-side remainder "on the
server" (one process here; the boundary is the same z hand-off the paper
prices). Runs reduced configs on CPU; full configs are exercised via the
dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import tiering
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ASSIGNED_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--split-tier", type=int, default=0,
                    help="DTFL split serving at this tier (0 = monolithic)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    full = get_config(args.arch)
    cfg = full if args.full_size else full.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = M.init(key, cfg)
    B = args.batch
    total = args.prompt_len + args.tokens

    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    cache = M.init_cache(cfg, B, total)
    if cfg.family == "encdec":
        batch = {"tokens": prompt,
                 "frontend": jnp.zeros((B, cfg.n_frontend_tokens,
                                        cfg.d_frontend or cfg.d_model))}
        enc = M.encode(params, cfg, batch)
        from repro.models.layers import cdtype
        dt = cdtype(cfg)
        hd = cfg.resolved_head_dim
        xk = jnp.stack([(enc.astype(dt) @ params["blocks"]["xattn"]["wk"][i].astype(dt))
                        .reshape(B, -1, cfg.n_kv_heads, hd) for i in range(cfg.n_layers)])
        xv = jnp.stack([(enc.astype(dt) @ params["blocks"]["xattn"]["wv"][i].astype(dt))
                        .reshape(B, -1, cfg.n_kv_heads, hd) for i in range(cfg.n_layers)])
        cache["layers"]["xk"], cache["layers"]["xv"] = xk, xv

    step = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c))

    if args.split_tier:
        cp, sp = tiering.split_params(params, cfg.replace(tie_embeddings=False)
                                      if cfg.tie_embeddings else cfg, args.split_tier)
        print(f"[serve] split-tier {args.split_tier}: client blocks="
              f"{jax.tree.leaves(cp['blocks'])[0].shape[0]} "
              f"server blocks={jax.tree.leaves(sp['blocks'])[0].shape[0]} "
              f"(z hand-off per token: {B * cfg.d_model * 2} bytes)")

    # prefill by stepping the prompt (simple reference path)
    t0 = time.time()
    tok = prompt[:, 0]
    out_tokens = [tok]
    for i in range(total - 1):
        logits, cache = step(params, tok, cache)
        if i + 1 < args.prompt_len:
            tok = prompt[:, i + 1]
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    seq = jnp.stack(out_tokens, 1)
    dt_all = time.time() - t0
    print(f"[serve] {args.arch}: {B} seqs x {total} steps in {dt_all:.1f}s "
          f"({B * total / dt_all:.1f} tok/s); sample: {np.asarray(seq[0])[:24].tolist()}")


if __name__ == "__main__":
    main()
