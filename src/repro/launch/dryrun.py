import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this driver:
  1. builds the production mesh (single-pod 16x16 or multi-pod 2x16x16),
  2. lowers + compiles the step (DTFL tier train / prefill / decode) with the
     baseline shardings from launch/specs.py,
  3. prints memory_analysis() (proves it fits) and cost_analysis(),
  4. extracts trip-count-aware FLOPs / HBM bytes / collective bytes from the
     compiled HLO (launch/hlo_analysis.py) and derives the roofline terms,
  5. writes a JSON artifact to experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch import hlo_analysis, specs as S, steps as step_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.shardctx import activation_sharding

OUT_DIR = "experiments/dryrun"


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode prices one token."""
    n_active = M.count_params_analytic(cfg.replace(tie_embeddings=False), active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token / sequence


def run_one(arch: str, shape_name: str, *, multi_pod: bool, tier: int | None = None,
            step: str | None = None, save: bool = True, verbose: bool = True,
            preset: str = "baseline", pad_vocab: int = 0) -> dict:
    cfg = get_config(arch)
    if pad_vocab:
        cfg = cfg.replace(pad_vocab_multiple=pad_vocab)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    builder = step_lib.builder_for(shape, step)
    kw = {}
    if builder is step_lib.build_dtfl_train:
        if tier is not None:
            kw["tier"] = tier
        kw["preset"] = preset
    if builder is step_lib.build_decode and preset != "baseline":
        kw["preset"] = preset
    built = builder(cfg, shape, mesh, **kw)

    t0 = time.time()
    with mesh:
        with activation_sharding(**_named(mesh, built["act_specs"])):
            jitted = jax.jit(
                built["fn"],
                in_shardings=_named(mesh, built["in_specs"]),
                out_shardings=_named(mesh, built["out_specs"]),
                donate_argnums=built["donate"],
            )
            lowered = jitted.lower(*built["args"])
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = hlo_analysis.flat_cost_analysis(compiled)
    txt = compiled.as_text()
    hlo = hlo_analysis.analyze(txt)
    terms = hlo_analysis.roofline_terms(hlo)
    mf = model_flops(built["cfg"], shape)
    n_dev = mesh.devices.size
    useful = mf / n_dev / max(hlo["flops"], 1.0)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "step": step or shape.kind,
        "preset": preset + ("+padvocab" if pad_vocab else ""),
        "tier": kw.get("tier", step_lib.DEFAULT_TIER if shape.kind == "train" else None),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops_flat": ca.get("flops"),
            "bytes_flat": ca.get("bytes accessed"),
        },
        "hlo_per_device": {
            "flops": hlo["flops"],
            "hbm_bytes": hlo["bytes"],
            "collective_bytes": hlo["collective_bytes_total"],
            "collectives": hlo["coll"],
        },
        "roofline": terms,
        "model_flops_total": mf,
        "useful_flops_ratio": useful,
    }
    if verbose:
        peak = (
            max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
        )
        print(
            f"[dryrun] {arch:24s} {shape_name:12s} mesh={rec['mesh']:8s} "
            f"lower={t_lower:5.1f}s compile={t_compile:6.1f}s "
            f"args/dev={ma.argument_size_in_bytes/2**30:6.2f}GiB "
            f"temp/dev={ma.temp_size_in_bytes/2**30:6.2f}GiB "
            f"t_comp={terms['compute_s']*1e3:8.2f}ms t_mem={terms['memory_s']*1e3:8.2f}ms "
            f"t_coll={terms['collective_s']*1e3:8.2f}ms dom={terms['dominant']}"
        )
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = "_mp" if multi_pod else ""
        tag = f"{arch}_{shape_name}{suffix}" + (f"_{step}" if step else "")
        if preset != "baseline":
            tag += f"_{preset}"
        if pad_vocab:
            tag += f"_pv{pad_vocab}"
        with open(f"{OUT_DIR}/{tag}.json", "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all (arch x shape) combos")
    ap.add_argument("--tier", type=int, default=None)
    ap.add_argument("--step", choices=list(step_lib.BUILDERS), default=None)
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--preset", default="baseline", choices=["baseline", "seqpar", "megatron_sp", "serve_dp", "serve_seq"])
    ap.add_argument("--pad-vocab", type=int, default=0)
    args = ap.parse_args(argv)

    combos = (
        [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape in combos:
        try:
            run_one(arch, shape, multi_pod=args.multi_pod, tier=args.tier,
                    step=args.step, save=not args.no_save, preset=args.preset,
                    pad_vocab=args.pad_vocab)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            print(f"[dryrun] FAIL {arch} {shape}: {e}")
            traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures")
        sys.exit(1)
    print(f"[dryrun] all {len(combos)} combination(s) lowered + compiled OK")


if __name__ == "__main__":
    main()
