"""Step builders for the dry-run / launcher: DTFL train, full train, prefill,
decode — each returns (fn, abstract_args, in_shardings, out_shardings,
donate) ready for jax.jit().lower().

The train step for the dry-run is the paper's technique: a DTFL tier step
(client local-loss update || server update) at a configurable tier
(default: mid tier), with Adam. ``--step full`` lowers the monolithic
baseline step instead.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.configs.base import ArchConfig, InputShape
from repro.core import local_loss, tiering
from repro.launch import specs as S
from repro.models import model as M

DEFAULT_TIER = 4  # paper's M=7; mid tier (1-based)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _param_like_specs(shapes_tree, mesh=None, preset="baseline"):
    return S.tree_pspecs(shapes_tree, mesh, preset)


# ===========================================================================
# DTFL tier train step
# ===========================================================================

def build_dtfl_train(cfg: ArchConfig, shape: InputShape, mesh, *, tier: int = DEFAULT_TIER, preset: str = "baseline"):
    cfg = cfg.replace(tie_embeddings=False)
    opt = optim.adam(1e-3)
    key = jax.random.PRNGKey(0)

    params_shape = _abstract(lambda: M.init(key, cfg))
    state_shape = _abstract(
        lambda: local_loss.init_tier_state(key, cfg, M.init(key, cfg), tier, opt)
    )

    step = local_loss.make_dtfl_train_step(cfg, opt)
    batch = S.input_specs(cfg, shape)

    cps = _param_like_specs(state_shape.client_params, mesh)
    aps = _param_like_specs(state_shape.aux_params, mesh)
    sps = _param_like_specs(state_shape.server_params, mesh)
    state_specs = local_loss.DTFLState(
        client_params=cps,
        aux_params=aps,
        server_params=sps,
        client_opt=S.opt_state_pspecs(state_shape.client_opt, cps),
        aux_opt=S.opt_state_pspecs(state_shape.aux_opt, aps),
        server_opt=S.opt_state_pspecs(state_shape.server_opt, sps),
    )
    batch_specs = S.batch_pspecs(cfg, shape, mesh)
    metric_specs = local_loss.DTFLMetrics(P(), P())

    return dict(
        fn=step,
        args=(state_shape, batch),
        in_specs=(state_specs, batch_specs),
        out_specs=(state_specs, metric_specs),
        donate=(0,),
        act_specs=S.activation_pspecs(cfg, shape, mesh, preset),
        cfg=cfg,
        n_layers=cfg.n_layers,
    )


# ===========================================================================
# monolithic train step (baseline / FedAvg-style)
# ===========================================================================

def build_full_train(cfg: ArchConfig, shape: InputShape, mesh):
    opt = optim.adam(1e-3)
    key = jax.random.PRNGKey(0)
    params_shape = _abstract(lambda: M.init(key, cfg))
    opt_shape = _abstract(lambda: opt.init(M.init(key, cfg)))
    step = local_loss.make_full_train_step(cfg, opt)
    batch = S.input_specs(cfg, shape)

    p_specs = _param_like_specs(params_shape, mesh)
    o_specs = S.opt_state_pspecs(opt_shape, p_specs)
    return dict(
        fn=step,
        args=(params_shape, opt_shape, batch),
        in_specs=(p_specs, o_specs, S.batch_pspecs(cfg, shape, mesh)),
        out_specs=(p_specs, o_specs, P()),
        donate=(0, 1),
        act_specs=S.activation_pspecs(cfg, shape, mesh),
        cfg=cfg,
        n_layers=cfg.n_layers,
    )


# ===========================================================================
# serve: prefill (full forward) and decode (one token + cache)
# ===========================================================================

def _bf16_params_shape(cfg):
    key = jax.random.PRNGKey(0)
    shapes = _abstract(lambda: M.init(key, cfg))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
        ),
        shapes,
    )


def build_prefill(cfg: ArchConfig, shape: InputShape, mesh):
    params_shape = _bf16_params_shape(cfg)

    def prefill(params, batch):
        logits, _ = M.forward(params, cfg, batch)
        return logits[:, -1]  # next-token logits

    batch = S.input_specs(cfg, shape)
    batch.pop("labels", None)
    bspecs = S.batch_pspecs(cfg, shape, mesh)
    bspecs.pop("labels", None)
    dp = S.data_axes(mesh) if shape.global_batch >= 16 else None
    out_spec = S._drop_indivisible(
        P(dp, "model"), (shape.global_batch, cfg.vocab), mesh
    )
    return dict(
        fn=prefill,
        args=(params_shape, batch),
        in_specs=(_param_like_specs(params_shape, mesh), bspecs),
        out_specs=out_spec,
        donate=(),
        act_specs=S.activation_pspecs(cfg, shape, mesh),
        cfg=cfg,
        n_layers=cfg.n_layers,
    )


def build_decode(cfg: ArchConfig, shape: InputShape, mesh, *, preset: str = "baseline"):
    params_shape = _bf16_params_shape(cfg)
    ins = S.input_specs(cfg, shape)
    cache_shape = ins["cache"]

    def serve_step(params, token, cache):
        return M.decode_step(params, cfg, token, cache)

    p_specs = _param_like_specs(params_shape, mesh, preset)
    c_specs = S.cache_pspecs(cache_shape, shape, mesh, preset)
    dp = S.data_axes(mesh) if shape.global_batch >= 16 else None
    tok_spec = P(dp)
    logits_spec = S._drop_indivisible(
        P(dp, "model"), (shape.global_batch, cfg.vocab), mesh
    )
    return dict(
        fn=serve_step,
        args=(params_shape, ins["token"], cache_shape),
        in_specs=(p_specs, tok_spec, c_specs),
        out_specs=(logits_spec, {"layers": c_specs["layers"], "pos": P()}),
        donate=(2,),
        act_specs=S.activation_pspecs(cfg, shape, mesh, preset),
        cfg=cfg,
        n_layers=cfg.n_layers,
    )


BUILDERS = {
    "train": build_dtfl_train,
    "full": build_full_train,
    "prefill": build_prefill,
    "decode": build_decode,
}


def builder_for(shape: InputShape, step: str | None = None):
    if step:
        return BUILDERS[step]
    return BUILDERS[{"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]]
