"""Production mesh construction.

Single pod : (data=16, model=16)            — 256 chips (v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     — 512 chips

Functions, not module constants, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry batch parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """1-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
