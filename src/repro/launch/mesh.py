"""Production mesh construction + the simulated client-axis mesh.

Single pod : (data=16, model=16)            — 256 chips (v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     — 512 chips
Federation : (clients=N,)                   — 1-D mesh over the simulated
             client axis (fed/execplan.py shards cohort programs over it)

Functions, not module constants, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import os

import jax
import numpy as np

CLIENT_AXIS = "clients"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry batch parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """1-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# simulated federation mesh (sharded client axis)
# ---------------------------------------------------------------------------

def ensure_sim_devices(n: int) -> None:
    """Make ``n`` host-platform devices visible BEFORE jax's backend inits.

    On CPU, jax exposes one device unless ``XLA_FLAGS`` carries
    ``--xla_force_host_platform_device_count=N``; this appends the flag to the
    environment so a 2-core container can exercise real N-way ``shard_map``
    sharding. Must run before anything touches jax device state — raises if
    the backend already initialized with fewer devices.
    """
    if n <= 1:
        return
    import re

    flag = "--xla_force_host_platform_device_count"
    cur = os.environ.get("XLA_FLAGS", "")
    m = re.search(rf"{flag}=(\d+)", cur)
    if m is None:
        os.environ["XLA_FLAGS"] = f"{cur} {flag}={n}".strip()
    elif int(m.group(1)) < n:
        # replace in place, don't append: a second copy of the flag leaves
        # XLA to pick a winner; pre-init the replacement applies cleanly
        os.environ["XLA_FLAGS"] = cur.replace(m.group(0), f"{flag}={n}")
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"jax sees {len(jax.devices())} device(s) after "
            f"ensure_sim_devices({n}) — its backend initialized before the "
            f"flag could apply; launch with XLA_FLAGS={flag}={n} instead"
        )


def make_sim_mesh(n: int | None = None, *, axis: str = CLIENT_AXIS):
    """1-D ``(clients=n)`` mesh over the first ``n`` visible devices.

    ``n=None`` uses every visible device. The federation plane shards the
    simulated-client axis of each cohort program over this mesh; a 1-device
    sim mesh is the degenerate (but still shard_map-routed) case the
    bit-equivalence tests pin down.
    """
    devs = jax.devices()
    n = len(devs) if n is None else int(n)
    if n < 1:
        raise ValueError(f"mesh needs >=1 device, got {n}")
    if len(devs) < n:
        raise RuntimeError(
            f"requested a {n}-device sim mesh but only {len(devs)} visible; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (or call ensure_sim_devices) before jax initializes"
        )
    return jax.sharding.Mesh(np.array(devs[:n]), (axis,))
