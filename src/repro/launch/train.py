"""Training entry point: DTFL federated training on any selectable arch.

CPU-runnable driver (reduced configs by default); on a real TPU deployment
the same flags select full configs and the production mesh. Examples:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --rounds 20
  PYTHONPATH=src python -m repro.launch.train --arch resnet-56 --clients 10 \
      --rounds 50 --target-acc 0.8 --scheduler dynamic
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro import optim
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.resnet_cifar import get_resnet
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import DATASETS, ClassImageTask, SeqTask
from repro.fed import (ChurnModel, DTFLTrainer, ExecPlan, HeteroEnv,
                       ResNetAdapter, SimClient, TransformerAdapter, TRAINERS)


def build_image_setup(cfg, args):
    base = DATASETS[args.dataset]
    task = ClassImageTask(n_classes=base.n_classes, image_size=cfg.image_size,
                          noise=base.noise, seed=base.seed)
    rng = np.random.default_rng(args.seed)
    labels = rng.integers(0, task.n_classes, args.samples)
    part_fn = iid_partition if args.iid else dirichlet_partition
    parts = part_fn(labels, args.clients, seed=args.seed)
    clients = [
        SimClient(i, ClientDataset(task, labels, parts[i], args.batch_size), None)
        for i in range(args.clients)
    ]
    return clients, make_eval_batch(task, 512)


class SeqClientDataset:
    """Token-LM per-client dataset with the ClientDataset interface."""

    def __init__(self, task: SeqTask, n_batches: int, batch_size: int, seq: int, seed: int):
        self.task, self._n, self.batch_size, self.seq, self.seed = task, n_batches, batch_size, seq, seed

    def __len__(self):
        return self._n * self.batch_size

    @property
    def n_batches(self):
        return self._n

    def epoch(self, epoch_seed: int):
        yield from self.task.batches(self.batch_size, self.seq, self._n,
                                     seed=self.seed * 7919 + epoch_seed)


def build_lm_setup(cfg, args):
    task = SeqTask(vocab=cfg.vocab)
    clients = [
        SimClient(i, SeqClientDataset(task, 2, args.batch_size, args.seq_len, i), None)
        for i in range(args.clients)
    ]
    ev = next(task.batches(args.batch_size, args.seq_len, 1, seed=99))
    return clients, ev


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet-56",
                    choices=ASSIGNED_ARCHS + ["resnet-56", "resnet-110"])
    ap.add_argument("--method", default="dtfl", choices=list(TRAINERS))
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--dataset", default="cifar10", choices=list(DATASETS))
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--full-size", action="store_true",
                    help="full config (TPU scale) instead of the reduced variant")
    ap.add_argument("--scheduler", default="dynamic")
    ap.add_argument("--engine", default=None, choices=["rounds", "events", "async"],
                    help="rounds: legacy scalar-clock synchronous loop; "
                         "events: discrete-event virtual clock (sync semantics, "
                         "supports churn); async: FedAT-style per-tier pacing "
                         "with staleness-weighted merges. Default: rounds "
                         "(async for --method fedat)")
    ap.add_argument("--exec", dest="exec_mode", default="cohort",
                    choices=["cohort", "loop", "sharded"],
                    help="cohort: vectorized tier-cohort programs (one "
                         "vmap+scan per tier); loop: per-client sequential "
                         "debug path; sharded: cohort programs with the "
                         "client axis split over a device mesh (psum "
                         "aggregation) — see --devices")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size for --exec sharded (default: all visible "
                         "devices). On CPU, forces "
                         "--xla_force_host_platform_device_count so N-way "
                         "sharding works on any host")
    ap.add_argument("--codec", default="identity",
                    help="communication codec for the three wires (activation "
                         "uplink z, client-model download, client-update "
                         "upload): identity | bf16 | int8 | topk<frac> (e.g. "
                         "topk0.05, with client-held error feedback). "
                         "identity is bit-for-bit the uncompressed path; "
                         "compressed codecs change the simulated comm times "
                         "AND what the tier scheduler re-tiers on")
    ap.add_argument("--n-groups", type=int, default=3,
                    help="speed groups for --engine async")
    ap.add_argument("--churn", action="store_true",
                    help="enable client churn (events/async engines only)")
    ap.add_argument("--churn-drop", type=float, default=0.1,
                    help="per-round mid-round dropout probability")
    ap.add_argument("--churn-switch", type=float, default=0.1,
                    help="per-round mid-round profile-switch probability")
    ap.add_argument("--churn-offline-frac", type=float, default=0.0,
                    help="fraction of the roster that starts offline and "
                         "arrives over time")
    ap.add_argument("--churn-rejoin", type=int, default=2,
                    help="rounds a dropped client stays offline")
    ap.add_argument("--target-acc", type=float, default=None)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dcor-alpha", type=float, default=0.0)
    ap.add_argument("--switch-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-every", type=int, default=10,
                    help="checkpoint every N rounds (with --out-ckpt)")
    ap.add_argument("--out-ckpt", default=None,
                    help="write resumable train-state checkpoints here")
    ap.add_argument("--resume", default=None,
                    help="resume from a --out-ckpt envelope: restores "
                         "params, per-tier aux heads, optimizer/scheduler "
                         "state, env profiles, and the RNG streams, then "
                         "continues deterministically (rounds/events only)")
    args = ap.parse_args(argv)

    # mesh sizing must land before anything initializes jax's backend
    if args.exec_mode == "sharded" and args.devices:
        from repro.launch.mesh import ensure_sim_devices

        ensure_sim_devices(args.devices)

    if args.arch.startswith("resnet"):
        full_cfg = get_resnet(args.arch)
        cfg = full_cfg if args.full_size else full_cfg.reduced()
        adapter = ResNetAdapter(cfg, cost_cfg=full_cfg, dcor_alpha=args.dcor_alpha)
        clients, eval_batch = build_image_setup(cfg, args)
    else:
        full_cfg = get_config(args.arch)
        cfg = full_cfg if args.full_size else full_cfg.reduced()
        adapter = TransformerAdapter(cfg, seq_len=args.seq_len, cost_cfg=full_cfg,
                                     dcor_alpha=args.dcor_alpha)
        clients, eval_batch = build_lm_setup(cfg, args)

    env = HeteroEnv(args.clients, switch_every=args.switch_every, seed=args.seed)
    trainer_cls = TRAINERS[args.method]
    kw = {"scheduler": args.scheduler} if args.method == "dtfl" else {}
    kw["exec_plan"] = ExecPlan.from_flags(args.exec_mode, devices=args.devices)
    kw["codec"] = args.codec
    trainer = trainer_cls(adapter, clients, env, optim.adam(args.lr), seed=args.seed, **kw)

    # engine defaults per method (fedat is async by construction); an
    # explicit --engine always wins, including fedat's rounds debug path
    engine = args.engine or ("async" if args.method == "fedat" else "rounds")
    churn = None
    if args.churn:
        if engine == "rounds":
            ap.error("--churn requires --engine events or --engine async")
        churn = ChurnModel(
            args.clients, drop_prob=args.churn_drop, switch_prob=args.churn_switch,
            start_offline_frac=args.churn_offline_frac,
            rejoin_after=args.churn_rejoin, seed=args.seed,
        )
    run_kw = {"engine": engine}
    if engine == "async":
        run_kw["n_groups"] = args.n_groups
    if args.out_ckpt:
        run_kw["checkpoint_path"] = args.out_ckpt
        run_kw["checkpoint_every"] = max(1, args.save_every)
    if args.resume:
        from repro import checkpoint as ckpt

        if engine == "async":
            ap.error("--resume supports --engine rounds|events only")
        if args.churn:
            ap.error("--resume with --churn is unsupported (churn state is "
                     "not checkpointed)")
        run_kw["resume"] = ckpt.load(args.resume)
        print(f"[train] resuming from {args.resume} at round "
              f"{int(run_kw['resume']['round'])}")

    t0 = time.time()
    logs = trainer.run(args.rounds, eval_batch, target_acc=args.target_acc,
                       participation=args.participation, verbose=True,
                       churn=churn, **run_kw)
    wall = time.time() - t0
    print(f"[train] {args.method} {args.arch}: {len(logs)} rounds, "
          f"sim_clock={logs[-1].clock:,.0f}s acc={logs[-1].acc:.3f} wall={wall:.0f}s")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([l.__dict__ for l in logs], f, default=str, indent=1)


if __name__ == "__main__":
    main()
