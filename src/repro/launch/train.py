"""Training entry point: CLI flags -> ``ExperimentSpec`` -> ``Federation``.

This module is pure translation: every flag maps onto one field of the
declarative spec tree in ``repro.api`` and the run itself is
``spec.build().run()`` — the same path the benchmarks, the sweep plane, and
the examples use, so the CLI cannot drift from them. String knobs
(``--method``, ``--scheduler``, ``--codec``, ``--arch``, ``--dataset``,
``--engine``, ``--exec``) are validated against the component registries at
argparse time; a typo fails immediately with the registered choice set.

CPU-runnable driver (reduced configs by default); on a real TPU deployment
the same flags select full configs and the production mesh. Examples:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --rounds 20
  PYTHONPATH=src python -m repro.launch.train --arch resnet-56 --clients 10 \
      --rounds 50 --target-acc 0.8 --scheduler dynamic
"""
from __future__ import annotations

import argparse
import json
import time

from repro import registry
from repro.api import (CheckpointSpec, ChurnSpec, CodecSpec, DataSpec,
                       EngineSpec, EnvSpec, ExecSpec, ExperimentSpec,
                       ModelSpec, SpecError, TrainerSpec)
# back-compat re-export: SeqClientDataset lived here before moving to the
# data plane
from repro.data.pipeline import SeqClientDataset  # noqa: F401


def _registry_type(reg):
    """argparse ``type=`` adapter: canonicalize through a registry, failing
    at PARSE time with the full registered choice set."""

    def parse(s: str):
        try:
            return reg.validate(s)
        except registry.RegistryError as e:
            raise argparse.ArgumentTypeError(str(e)) from None

    parse.__name__ = reg.kind.replace(" ", "_")
    return parse


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet-56",
                    type=_registry_type(registry.archs),
                    help="model family: " + ", ".join(registry.archs.names()))
    ap.add_argument("--method", default="dtfl",
                    type=_registry_type(registry.trainers),
                    help="algorithm: " + ", ".join(registry.trainers.names()))
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--population", type=int, default=None,
                    help="lazy client registry size (100k+ scale): per-client "
                         "state (data pipeline, env profile, scheduler row, "
                         "EF residual) materializes on first participation. "
                         "--samples becomes PER-CLIENT dataset size; combine "
                         "with --sample-size and --exec chunked")
    ap.add_argument("--sample-size", type=int, default=None,
                    help="exact clients sampled per round (instead of "
                         "--participation * population); rounds/events only")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--dataset", default="cifar10",
                    type=_registry_type(registry.datasets),
                    help="image dataset for resnet archs (transformer archs "
                         "always train the token-LM task): "
                         + ", ".join(registry.datasets.names()))
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--full-size", action="store_true",
                    help="full config (TPU scale) instead of the reduced variant")
    ap.add_argument("--scheduler", default="dynamic",
                    type=_registry_type(registry.schedulers),
                    help="tier scheduler spec: "
                         + " | ".join(registry.schedulers.choices()))
    ap.add_argument("--topology", default="server",
                    type=_registry_type(registry.topologies),
                    help="offload topology: server (classic DTFL) | pairing "
                         "(fast clients host slow clients' far halves; "
                         "implies --scheduler pairing)")
    ap.add_argument("--engine", default=None,
                    type=lambda s: s if s == "auto"  # the spec-level default
                    else _registry_type(registry.engines)(s),
                    help="rounds: legacy scalar-clock synchronous loop; "
                         "events: discrete-event virtual clock (sync "
                         "semantics, supports churn); async: FedAT-style "
                         "per-tier pacing with staleness-weighted merges. "
                         "Default: rounds (async for --method fedat)")
    ap.add_argument("--exec", dest="exec_mode", default="cohort",
                    type=_registry_type(registry.exec_modes),
                    help="cohort: vectorized tier-cohort programs (one "
                         "vmap+scan per tier); loop: per-client sequential "
                         "debug path; sharded: cohort programs with the "
                         "client axis split over a device mesh (psum "
                         "aggregation) — see --devices; chunked: the cohort "
                         "programs run chunk_size clients at a time (device "
                         "memory O(chunk), bit-identical to cohort) — see "
                         "--chunk-size")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size for --exec sharded (default: all visible "
                         "devices). On CPU, forces "
                         "--xla_force_host_platform_device_count so N-way "
                         "sharding works on any host")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="client-chunk length for --exec chunked (default "
                         "16)")
    ap.add_argument("--codec", default="identity",
                    type=_registry_type(registry.codecs),
                    help="communication codec for the three wires (activation "
                         "uplink z, client-model download, client-update "
                         "upload): " + " | ".join(registry.codecs.choices())
                         + ". identity is bit-for-bit the uncompressed path; "
                         "compressed codecs change the simulated comm times "
                         "AND what the tier scheduler re-tiers on")
    ap.add_argument("--n-groups", type=int, default=3,
                    help="speed groups for --engine async")
    ap.add_argument("--churn", action="store_true",
                    help="enable client churn (events/async engines only)")
    ap.add_argument("--churn-drop", type=float, default=0.1,
                    help="per-round mid-round dropout probability")
    ap.add_argument("--churn-switch", type=float, default=0.1,
                    help="per-round mid-round profile-switch probability")
    ap.add_argument("--churn-offline-frac", type=float, default=0.0,
                    help="fraction of the roster that starts offline and "
                         "arrives over time")
    ap.add_argument("--churn-rejoin", type=int, default=2,
                    help="rounds a dropped client stays offline")
    ap.add_argument("--target-acc", type=float, default=None)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dcor-alpha", type=float, default=0.0)
    ap.add_argument("--switch-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the RoundLog stream here as JSON")
    ap.add_argument("--out-spec", default=None,
                    help="write the resolved ExperimentSpec JSON here (also "
                         "accepted by benchmarks/sweep.py --spec)")
    ap.add_argument("--save-every", type=int, default=10,
                    help="checkpoint every N rounds (with --out-ckpt)")
    ap.add_argument("--out-ckpt", default=None,
                    help="write resumable train-state checkpoints here")
    ap.add_argument("--resume", default=None,
                    help="resume from a --out-ckpt envelope: restores "
                         "params, per-tier aux heads, optimizer/scheduler "
                         "state, env profiles, and the RNG streams, then "
                         "continues deterministically (rounds/events only). "
                         "The envelope's spec stamp must match this run's "
                         "spec hash")
    return ap


def spec_from_args(args) -> ExperimentSpec:
    """The flags -> spec translation (see README for the full flag table)."""
    kind = registry.archs.meta(args.arch)["kind"]
    churn = None
    if args.churn:
        churn = ChurnSpec(drop=args.churn_drop, switch=args.churn_switch,
                          offline_frac=args.churn_offline_frac,
                          rejoin=args.churn_rejoin)
    return ExperimentSpec(
        model=ModelSpec(arch=args.arch, full_size=args.full_size),
        data=DataSpec(dataset=args.dataset if kind == "resnet" else "lm",
                      clients=args.clients, population=args.population,
                      samples=args.samples,
                      batch_size=args.batch_size, iid=args.iid,
                      seq_len=args.seq_len),
        env=EnvSpec(switch_every=args.switch_every),
        trainer=TrainerSpec(method=args.method, scheduler=args.scheduler,
                            topology=args.topology,
                            lr=args.lr, dcor_alpha=args.dcor_alpha,
                            sample_size=args.sample_size),
        engine=EngineSpec(name=args.engine or "auto", n_groups=args.n_groups,
                          churn=churn),
        exec=ExecSpec(mode=args.exec_mode, devices=args.devices,
                      chunk_size=args.chunk_size),
        codec=CodecSpec(name=args.codec),
        checkpoint=CheckpointSpec(path=args.out_ckpt,
                                  every=max(1, args.save_every),
                                  resume=args.resume),
        rounds=args.rounds, target_acc=args.target_acc,
        participation=args.participation, seed=args.seed,
    )


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)

    # mesh sizing must land before anything initializes jax's backend
    if args.exec_mode == "sharded" and args.devices:
        from repro.launch.mesh import ensure_sim_devices

        ensure_sim_devices(args.devices)

    try:
        spec = spec_from_args(args)
    except SpecError as e:
        ap.error(str(e))
    if args.out_spec:
        with open(args.out_spec, "w") as f:
            f.write(spec.to_json(indent=1))

    fed = spec.build()
    t0 = time.time()
    try:
        logs = fed.run(verbose=True)
    except SpecError as e:  # e.g. resume-envelope spec-hash mismatch
        ap.error(str(e))
    wall = time.time() - t0
    print(f"[train] {args.method} {args.arch}: {len(logs)} rounds, "
          f"sim_clock={logs[-1].clock:,.0f}s acc={logs[-1].acc:.3f} wall={wall:.0f}s")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([l.__dict__ for l in logs], f, default=str, indent=1)


if __name__ == "__main__":
    main()
