"""Launcher: production mesh, dry-run driver, training/serving entry points."""
