"""Trip-count-aware roofline extraction from compiled HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of its
trip count (verified empirically — DESIGN.md §7), which under-counts
scan-over-layers models by ~L x. This module re-derives the three roofline
inputs directly from ``compiled.as_text()``:

  * dot FLOPs           — every `dot` op: 2 * prod(result dims) * contracted
  * HBM byte traffic    — per op: result bytes + operand bytes (the
                          HloCostAnalysis convention), fusions counted at
                          their boundary (internals excluded)
  * collective bytes    — result-shape bytes of all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute
                          (all-reduce weighted 2x for the ring's
                          reduce-scatter + all-gather phases)

All three are aggregated recursively through `while` ops using the
`known_trip_count` the compiler records in backend_config. Conditionals are
counted once (max branch would be tighter; branches here are tiny).
Numbers are PER DEVICE (SPMD module is per-partition).
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops HloCostAnalysis treats as free (no real data movement)
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples summed."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


VMEM_RESIDENT = 4 * 2**20  # operands smaller than this are assumed to stay
                           # VMEM-resident across loop iterations


@dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    resident_bytes: float = 0.0  # small-operand reads, counted once per loop
    coll_bytes: dict = field(default_factory=dict)
    while_calls: list = field(default_factory=list)  # (body_name, trip)
    cond_calls: list = field(default_factory=list)   # branch computation names
    fusion_calls: list = field(default_factory=list) # called computations (flops only)
    fusion_ops: list = field(default_factory=list)   # (called, res_b, min_op_b, sum_op_b)
    has_slicing: bool = False


_OP_RE = re.compile(
    r"^\s*(?:ROOT )?(%[\w\.\-]+) = ((?:\([^)]*\)|[\w\[\],\{\}]+?)) ([\w\-]+)\((.*)$"
)
# computation headers start at column 0: "%name (params) -> type {" / "ENTRY ..."
_COMP_HDR = re.compile(r"^(ENTRY )?(%?[\w\.\-]+)\s*\(")


def parse_hlo(txt: str) -> tuple[dict[str, CompStats], str]:
    """Returns ({computation: stats}, entry_name)."""
    comps: dict[str, CompStats] = {}
    entry = None
    cur: CompStats | None = None
    cur_name = None
    symtab: dict[str, str] = {}

    for raw in txt.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line) if not raw.startswith(" ") else None
        if hdr and line.endswith("{") and " -> " in line:
            cur_name = hdr.group(2).lstrip("%")
            cur = comps.setdefault(cur_name, CompStats())
            if hdr.group(1):
                entry = cur_name
            symtab = {}
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        res_name, res_type, opcode, rest = m.groups()
        symtab[res_name] = res_type
        res_bytes = _shape_bytes(res_type)

        # operand bytes: resolve %refs in the argument list before attrs
        arg_str = rest.split("), ")[0]
        operand_bytes = 0
        resident_bytes = 0
        operand_types = []
        for ref in re.findall(r"%[\w\.\-]+", arg_str):
            t = symtab.get(ref)
            if t:
                b = _shape_bytes(t)
                if b < VMEM_RESIDENT:
                    resident_bytes += b
                else:
                    operand_bytes += b
                operand_types.append(t)
        cur.resident_bytes += resident_bytes

        if opcode.startswith("fusion"):
            # boundary traffic only; FLOPs recursed; slicing fusions fixed in
            # _finalize_fusion_bytes (count window, not whole buffers)
            mfc = re.search(r"calls=(%[\w\.\-]+)", line)
            called = mfc.group(1).lstrip("%") if mfc else ""
            all_ops = [_shape_bytes(t) for t in operand_types] or [0]
            cur.fusion_ops.append(
                (called, res_bytes, min(all_ops), res_bytes + sum(all_ops))
            )
            if called:
                cur.fusion_calls.append(called)
        elif opcode == "while":
            tc = 1
            mt = re.search(r'known_trip_count[\\"={:]+n[\\"]*[:=][\\"]*(\d+)', line)
            if mt:
                tc = int(mt.group(1))
            mb = re.search(r"body=(%[\w\.\-]+)", line)
            if mb:
                cur.while_calls.append((mb.group(1).lstrip("%"), tc))
        elif opcode == "conditional":
            for mb in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=(%[\w\.\-]+))", line):
                grp = mb.group(1) or mb.group(2) or ""
                for name in re.findall(r"%?([\w\.\-]+)", grp):
                    cur.cond_calls.append(name)
        elif opcode == "dot":
            flops = _dot_flops(line, res_type, operand_types)
            cur.flops += flops
            cur.bytes += res_bytes + operand_bytes
        elif opcode == "convolution":
            cur.flops += _conv_flops(line, res_type, operand_types)
            cur.bytes += res_bytes + operand_bytes
        elif any(opcode.startswith(c) for c in _COLLECTIVES):
            base = next(c for c in _COLLECTIVES if opcode.startswith(c))
            if opcode.endswith("-done"):
                continue  # counted at -start
            w = 2.0 if base == "all-reduce" else 1.0
            cur.coll_bytes[base] = cur.coll_bytes.get(base, 0.0) + w * res_bytes
            cur.bytes += res_bytes + operand_bytes
        elif opcode in _FREE_OPS:
            pass
        elif opcode == "copy":
            cur.bytes += 2 * res_bytes  # read + write, no operand double-count
        elif opcode in ("dynamic-slice", "slice", "gather"):
            # reads only the sliced window, never the whole operand
            cur.bytes += 2 * res_bytes
            cur.resident_bytes -= min(cur.resident_bytes, resident_bytes)
            cur.has_slicing = True
        elif opcode in ("dynamic-update-slice", "scatter"):
            # writes only the update window (aliased in place on TPU)
            upd = operand_types[1] if len(operand_types) > 1 else res_type
            cur.bytes += 2 * _shape_bytes(upd)
            cur.resident_bytes -= min(cur.resident_bytes, resident_bytes)
            cur.has_slicing = True
        else:
            cur.bytes += res_bytes + operand_bytes
    _finalize_fusion_bytes(comps)
    return comps, entry or "main"


def _finalize_fusion_bytes(comps: dict[str, CompStats]) -> None:
    """Charge fusion boundaries. A fusion whose computation slices (dynamic-
    slice / DUS / gather / scatter) touches only its window: count
    2 * min(result, smallest operand) — exact for scan xs-slicing and cache
    updates, conservative for mixed fusions. Other fusions pay full
    result + operands."""
    for st in comps.values():
        for called, res_b, min_op_b, full_b in st.fusion_ops:
            sub = comps.get(called)
            if sub is not None and sub.has_slicing:
                st.bytes += 2 * min(res_b, min_op_b) if min_op_b else 2 * res_b
            else:
                st.bytes += full_b


def _dot_flops(line: str, res_type: str, operand_types: list[str]) -> float:
    res_dims = _shape_dims(res_type)
    lhs = operand_types[0] if operand_types else ""
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contracted = 1
    if mc and lhs:
        ldims = _shape_dims(lhs)
        for d in mc.group(1).split(","):
            if d:
                contracted *= ldims[int(d)]
    return 2.0 * math.prod(res_dims or [1]) * contracted


def _conv_flops(line: str, res_type: str, operand_types: list[str]) -> float:
    res_dims = _shape_dims(res_type)
    rhs = operand_types[1] if len(operand_types) > 1 else ""
    rd = _shape_dims(rhs)
    # 2 * output elements * kernel volume * input channels (approx: prod(rhs)/out_ch)
    k = math.prod(rd) / (rd[-1] if rd else 1) if rd else 1
    return 2.0 * math.prod(res_dims or [1]) * k


def aggregate(comps: dict[str, CompStats], entry: str) -> dict:
    """Recursive trip-count-weighted totals from the entry computation."""
    memo: dict[str, dict] = {}

    def visit(name: str, stack=()) -> dict:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}}
        st = comps[name]
        total = {"flops": st.flops, "bytes": st.bytes + st.resident_bytes,
                 "coll": dict(st.coll_bytes)}
        for body, trip in st.while_calls:
            sub = visit(body, stack + (name,))
            total["flops"] += trip * sub["flops"]
            # big operands re-stream from HBM every iteration; small
            # (<4 MiB) loop operands stay VMEM-resident -> counted once
            total["bytes"] += trip * (sub["bytes"] - sub.get("res_once", 0.0)) + sub.get("res_once", 0.0)
            for k, v in sub["coll"].items():
                total["coll"][k] = total["coll"].get(k, 0.0) + trip * v
        for branch in st.cond_calls:
            sub = visit(branch, stack + (name,))
            total["flops"] += sub["flops"]
            total["bytes"] += sub["bytes"]
            for k, v in sub["coll"].items():
                total["coll"][k] = total["coll"].get(k, 0.0) + v
        for fc in st.fusion_calls:
            sub = visit(fc, stack + (name,))
            total["flops"] += sub["flops"]   # bytes intentionally excluded
        total["res_once"] = st.resident_bytes + sum(
            visit(b, stack + (name,)).get("res_once", 0.0)
            for b, _ in st.while_calls
        ) + sum(visit(b, stack + (name,)).get("res_once", 0.0) for b in st.cond_calls)
        memo[name] = total
        return total

    return visit(entry)


def analyze(txt: str) -> dict:
    comps, entry = parse_hlo(txt)
    out = aggregate(comps, entry)
    out["collective_bytes_total"] = sum(out["coll"].values())
    return out


def flat_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a plain dict.

    Older jax versions return a one-element list of per-program dicts; newer
    ones return the dict directly (and may return None for some backends).
    Callers should never index the raw result.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


# ---------------------------------------------------------------------------
# roofline terms (per device); v5e constants from the assignment
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s per link (~per chip, one direction)


def roofline_terms(analysis: dict) -> dict:
    compute = analysis["flops"] / PEAK_FLOPS
    memory = analysis["bytes"] / HBM_BW
    collective = analysis["collective_bytes_total"] / ICI_BW
    dom = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dom,
    }
