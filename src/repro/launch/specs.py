"""Input ShapeDtypeStructs + sharding rules for every (arch x shape).

Baseline sharding scheme (DESIGN.md §5):
  * batch            -> ("pod","data") axes
  * Megatron axis    -> "model": attention heads / FFN width / vocab / experts
  * FSDP axis        -> "data" on the other weight dim (optimizer state and
    fp32 master params are fully sharded; XLA all-gathers weights per layer)
  * activations      -> (batch -> data axes, d_model -> "model")
  * KV caches        -> (batch -> data, head_dim -> "model")  [head counts are
    not always divisible by 16; head_dim always is]

``long_500k`` has global_batch=1 < 16, so its batch dims stay unsharded
(the data axis idles; noted in the roofline).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import data_axes
from repro.models import model as M

Params = Any

# weight-name classes for the sharding rules
_COL = {"wq", "wk", "wv", "w1", "w3", "w_up", "w_gate", "w_in", "w_dt", "w", "proj"}
_ROW = {"wo", "w2", "w_down", "w_out"}
_REPL = {"conv", "a_log", "d_skip", "b_dt", "b_if", "b", "r", "w_bc", "router"}


# ===========================================================================
# parameter shardings
# ===========================================================================

def param_pspec(path: tuple, leaf) -> P:
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = p.key
            break
        if hasattr(p, "name"):
            name = p.name
            break
    nd = len(leaf.shape)
    lead = (None,) * (nd - 2)

    if name == "embed":
        return P("model", "data")
    if name == "lm_head":
        return P("data", "model")
    if name == "front_proj":
        return P(None, None)
    if name in ("we1", "we3"):          # (L, E, D, F): experts on model, FSDP on D
        return P(None, "model", "data", None)
    if name == "we2":                    # (L, E, F, D)
        return P(None, "model", None, "data")
    if name in _REPL or nd < 2:
        return P(*((None,) * nd))
    if name in _COL:
        return P(*lead, "data", "model")
    if name in _ROW:
        return P(*lead, "model", "data")
    return P(*((None,) * nd))


def _drop_indivisible(spec: P, shape: tuple, mesh) -> P:
    """jit in_shardings require exact divisibility (unlike internal
    constraints, which pad): drop mesh axes that don't divide the dim —
    e.g. vocab 51865 / 32001 / 49155 fall back to unsharded vocab."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= sizes[a]
        fixed.append(ax if dim % n == 0 else None)
    return P(*fixed)


def _strip_fsdp(spec: P) -> P:
    """Serving params: drop the 'data' (FSDP) axis so weight shards stay
    resident — decode cannot afford per-token weight regathers."""
    return P(*(None if ax == "data" else ax for ax in spec))


def tree_pspecs(tree_shapes, mesh=None, preset: str = "baseline") -> Any:
    specs = jax.tree_util.tree_map_with_path(param_pspec, tree_shapes)
    if preset in ("serve_dp", "serve_seq"):
        specs = jax.tree.map(_strip_fsdp, specs,
                             is_leaf=lambda x: isinstance(x, P))
    if mesh is None:
        return specs
    return jax.tree.map(
        lambda s, l: _drop_indivisible(s, l.shape, mesh), specs, tree_shapes
    )


def opt_state_pspecs(opt_shapes, param_specs) -> Any:
    """Adam-like state: m/v mirror params; scalars replicated."""
    out = {}
    for k, v in opt_shapes.items():
        if k in ("m", "v", "mu"):
            out[k] = param_specs
        else:
            out[k] = P()
    return out


# ===========================================================================
# activation / batch shardings
# ===========================================================================

def batch_pspecs(cfg: ArchConfig, shape: InputShape, mesh) -> dict:
    dp = data_axes(mesh)
    bdim = dp if shape.global_batch >= 16 else None
    specs = {
        "tokens": P(bdim, None),
        "labels": P(bdim, None),
    }
    if cfg.frontend != "none":
        specs["frontend"] = P(bdim, None, None)
    return specs


def activation_pspecs(cfg: ArchConfig, shape: InputShape, mesh,
                       preset: str = "baseline") -> dict:
    dp = data_axes(mesh)
    bdim = dp if shape.global_batch >= 16 else None
    if preset in ("serve_dp", "serve_seq"):
        return {
            "act": P(bdim, None, None),
            "z": P(bdim, None, None),
            "heads": None,
            "logits": P(bdim, "model") if cfg.vocab % 16 == 0 else P(bdim, None),
            "dec_qkv_pre": P(bdim, None, "model", None),
            "dec_qkv": P(bdim, None, None, None),
        }
    if preset == "megatron_sp":
        # Megatron sequence parallelism: residual stream seq-sharded (norms
        # and residual adds collective-free), block interior head/hidden
        # tensor-parallel (weight grads stay shard-local, no dW psums).
        # GSPMD inserts AG(x) at block entry and RS at block exit.
        return {
            "act": P(bdim, "model", None),
            "z": P(bdim, "model", None),
            "heads": P(bdim, None, "model", None),
            "logits": P(bdim, None, "model"),
        }
    if preset == "seqpar":
        # Sequence parallelism (beyond-paper perf preset, EXPERIMENTS.md
        # §Perf): activations sharded over SEQUENCE on the model axis.
        # SwiGLU/norms run fully seq-sharded with NO collectives; attention
        # all-gathers only the GQA-small k/v instead of psumming full-d_model
        # activations every layer.
        return {
            "act": P(bdim, "model", None),
            "z": P(bdim, "model", None),
            "heads": None,                      # grouped GQA attention, no repeat
            "kv": P(bdim, "model", None, None), # k/v seq-sharded pre-gather
            "logits": P(bdim, "model", None),   # vocab dim unsharded; seq sharded
            "q_chunk": shape.seq_len,           # no inner q scan: chunk reshape
                                                # fights the seq sharding
        }
    return {
        "act": P(bdim, None, "model"),
        # the DTFL hand-off (the tensor the paper prices as D_size): batch
        # stays data-parallel, d_model sharded over "model" for memory
        "z": P(bdim, None, "model"),
        # attention q/k/v (B, S, H, hd): heads on "model" (GSPMD pads
        # non-divisible head counts)
        "heads": P(bdim, None, "model", None),
        # logits (B, S, V): vocab on "model" (internal constraint pads)
        "logits": P(bdim, None, "model"),
    }


def cache_pspec(path: tuple, leaf, *, bdim) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    nd = len(leaf.shape)
    if name == "pos":
        return P()
    if "mamba" in names and name == "h":      # (L, B, di, N)
        return P(None, bdim, "model", None)
    if nd == 5:                                # (L, B, W, KV, hd)
        return P(None, bdim, None, None, "model")
    if nd == 4:                                # states (L, B, H, dh) / conv hist
        return P(None, bdim, None, "model")
    if nd == 3:
        return P(None, bdim, None)
    return P(*((None,) * nd))


def cache_pspecs(cache_shapes, shape: InputShape, mesh, preset: str = "baseline") -> Any:
    dp = data_axes(mesh)
    bdim = dp if shape.global_batch >= 16 else None
    if preset in ("serve_dp", "serve_seq"):
        # Serving presets (EXPERIMENTS.md §Perf):
        #   serve_dp : cache sharded on BATCH only (replicated over model) —
        #              attention fully local per batch shard.
        #   serve_seq: additionally shards the cache WINDOW over the model
        #              axis (flash-decoding): each device attends its slice
        #              of history; the softmax over the sharded window costs
        #              only (B, H)-sized stat psums. 16x less cache/device.
        def spec(path, leaf):
            names = [q.key for q in path if hasattr(q, "key")]
            name = names[-1] if names else ""
            nd = len(leaf.shape)
            if name == "pos":
                return P()
            if preset == "serve_seq" and nd == 5 and name in ("k", "v", "xk", "xv"):
                return P(None, bdim, "model", None, None)
            return P(None, bdim, *([None] * (nd - 2)))

        return jax.tree_util.tree_map_with_path(spec, cache_shapes)
    return jax.tree_util.tree_map_with_path(
        functools.partial(cache_pspec, bdim=bdim), cache_shapes
    )


# ===========================================================================
# input ShapeDtypeStructs
# ===========================================================================

def frontend_spec(cfg: ArchConfig, batch: int):
    d = cfg.d_frontend or cfg.d_model
    return jax.ShapeDtypeStruct((batch, cfg.n_frontend_tokens, d), jnp.bfloat16)


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Abstract stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.frontend != "none":
            specs["frontend"] = frontend_spec(cfg, B)
        return specs
    # decode: one token + a seq_len cache
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    long = shape.seq_len > 100_000
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, S, long_context=long)
    )
    return {"token": token, "cache": cache}


def sharded(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
