"""repro.checkpoint: pytree round-trips + the resumable-training envelope.

Covers the NamedTuple flatten bug (NamedTuples used to collapse to plain
tuples, silently changing pytree structure on load), the rng stream
(de)serialization, and the end-to-end guarantee the envelope exists for:
run N rounds straight == run k rounds, checkpoint, resume in a FRESH
process-state trainer, run N-k more — bit-for-bit.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.core.local_loss import DTFLState
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import DTFLTrainer, FedAvgTrainer, HeteroEnv, ResNetAdapter, SimClient
from repro.fed.adapter import DTFLStepState


# ---------------------------------------------------------------------------
# pytree structure round-trips
# ---------------------------------------------------------------------------

def roundtrip(tmp_path, tree):
    p = os.path.join(str(tmp_path), "ck.npz")
    ckpt.save(p, tree)
    return ckpt.load(p)


def test_namedtuple_structure_preserved(tmp_path):
    opt = optim.adam(1e-3)
    params = {"w": np.ones((3, 2), np.float32), "b": np.zeros(2, np.float32)}
    tree = {
        "step": DTFLStepState(params, params, params,
                              opt.init(params), opt.init(params), opt.init(params)),
        "state": DTFLState(params, params, params,
                           opt.init(params), opt.init(params), opt.init(params)),
        "mixed": [1, ("a-tuple", np.arange(3)), {"k": (np.float32(2.5),)}],
    }
    out = roundtrip(tmp_path, tree)
    # the seed bug: NamedTuples came back as plain tuples, so the treedefs
    # diverged and jax.tree.map(tree, restored) blew up
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert isinstance(out["step"], DTFLStepState)
    assert isinstance(out["state"], DTFLState)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_namedtuple_usable_with_tree_map(tmp_path):
    s = DTFLStepState(*(np.full(2, float(i)) for i in range(6)))
    out = roundtrip(tmp_path, s)
    summed = jax.tree.map(lambda a, b: a + b, s, out)  # requires same treedef
    assert isinstance(summed, DTFLStepState)
    np.testing.assert_array_equal(np.asarray(summed.client), 0.0)


def test_plain_containers_round_trip(tmp_path):
    tree = {"l": [np.arange(2), [np.arange(3)]], "t": (np.float64(1.5),),
            "scalar": np.int32(7)}
    out = roundtrip(tmp_path, tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert isinstance(out["t"], tuple) and isinstance(out["l"], list)


def test_empty_containers_round_trip(tmp_path):
    """Empty dict/list/tuple nodes must survive — without the marker they
    contribute no paths and vanish, shifting NamedTuple fields on load
    (e.g. FedGKT's teacher cache checkpointed before the first server
    phase)."""
    tree = {"teacher": {}, "l": [], "t": (),
            "nt": DTFLStepState({"w": np.ones(2)}, {}, [],
                                (np.arange(2),), {"m": {}}, np.int32(1)),
            "nested": {"a": {}, "b": [np.ones(1)]}}
    out = roundtrip(tmp_path, tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert out["teacher"] == {} and out["l"] == [] and out["t"] == ()
    assert out["nt"].aux == {} and out["nt"].server == []
    assert int(out["nt"].s_opt) == 1  # fields did not shift


def test_rng_pack_roundtrip_continues_stream():
    g = np.random.default_rng(123)
    g.random(7)
    g.integers(0, 50, 11)
    h = ckpt.unpack_rng(ckpt.pack_rng(g))
    np.testing.assert_array_equal(g.random(16), h.random(16))
    np.testing.assert_array_equal(g.choice(100, 8, replace=False),
                                  h.choice(100, 8, replace=False))


def test_rng_pack_rejects_non_pcg64():
    legacy = np.random.Generator(np.random.MT19937(0))
    with pytest.raises(ValueError):
        ckpt.pack_rng(legacy)


# ---------------------------------------------------------------------------
# save -> resume -> continue determinism (the envelope's contract)
# ---------------------------------------------------------------------------

def _setup(n_clients=4, per=40):
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, per * n_clients)
    clients = [
        SimClient(i, ClientDataset(task, labels, np.arange(i * per, (i + 1) * per), 16), None)
        for i in range(n_clients)
    ]
    return (ResNetAdapter(cfg, cost_cfg=RESNET56), clients,
            make_eval_batch(task, 64))


def _trainer(adapter, clients, cls=DTFLTrainer):
    # switch_every=2 so the env's profile-switch rng stream is exercised
    # across the checkpoint boundary
    return cls(adapter, clients, HeteroEnv(len(clients), switch_every=2, seed=0),
               optim.adam(1e-3), seed=0)


@pytest.mark.parametrize("engine", ["rounds", "events"])
def test_resume_continues_bit_for_bit(tmp_path, engine):
    p = os.path.join(str(tmp_path), "state.npz")
    adapter, clients, ev = _setup()
    straight = _trainer(adapter, clients)
    logs_straight = straight.run(4, ev, participation=0.75, engine=engine)

    first = _trainer(*_setup()[:2])
    first.run(2, ev, participation=0.75, engine=engine,
              checkpoint_path=p, checkpoint_every=2)
    resumed = _trainer(*_setup()[:2])
    logs_resumed = resumed.run(4, ev, participation=0.75, engine=engine,
                               resume=ckpt.load(p))

    assert [l.round for l in logs_resumed] == [2, 3]
    assert logs_resumed[-1].clock == pytest.approx(logs_straight[-1].clock, rel=1e-12)
    assert logs_resumed[-1].acc == logs_straight[-1].acc
    for a, b in zip(jax.tree.leaves(straight.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for m in straight.aux:
        for a, b in zip(jax.tree.leaves(straight.aux[m]),
                        jax.tree.leaves(resumed.aux[m])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # scheduler EMA history resumed too
    for c1, c2 in zip(straight.sched.clients, resumed.sched.clients):
        assert c1.tier == c2.tier
        for m in c1.ema:
            assert c1.ema[m].value == pytest.approx(c2.ema[m].value, rel=1e-12)


@pytest.mark.parametrize("cls_name", ["fedavg", "tifl", "fedgkt"])
def test_resume_baseline_trainer(tmp_path, cls_name):
    from repro.fed import TRAINERS

    cls = TRAINERS[cls_name]
    p = os.path.join(str(tmp_path), "state.npz")
    adapter, clients, ev = _setup()
    straight = _trainer(adapter, clients, cls=cls)
    logs_straight = straight.run(3, ev, engine="rounds")

    first = _trainer(*_setup()[:2], cls=cls)
    first.run(2, ev, engine="rounds", checkpoint_path=p, checkpoint_every=1)
    resumed = _trainer(*_setup()[:2], cls=cls)
    logs_resumed = resumed.run(3, ev, engine="rounds", resume=ckpt.load(p))
    # trainer-specific server state must ride the envelope: TiFL's tier
    # rotation + speed profile, FedGKT's edge/server/aux/teacher state
    assert logs_resumed[-1].clock == pytest.approx(logs_straight[-1].clock, rel=1e-12)
    for a, b in zip(jax.tree.leaves(straight.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if cls_name == "tifl":
        assert straight._round_robin == resumed._round_robin
        assert straight._speed_obs == resumed._speed_obs
    if cls_name == "fedgkt":
        assert set(straight._teacher) == set(resumed._teacher)
        for a, b in zip(jax.tree.leaves(straight.server_params),
                        jax.tree.leaves(resumed.server_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_carries_last_eval_acc(tmp_path):
    """With eval_every > 1, non-eval rounds after a resume must report the
    last EVALUATED accuracy from the envelope, not 0.0 — otherwise logs and
    target_acc early-stops diverge from an uninterrupted run."""
    p = os.path.join(str(tmp_path), "state.npz")
    adapter, clients, ev = _setup()
    straight = _trainer(adapter, clients)
    logs_straight = straight.run(3, ev, eval_every=2, engine="rounds")

    first = _trainer(*_setup()[:2])
    first.run(1, ev, eval_every=2, engine="rounds",
              checkpoint_path=p, checkpoint_every=1)
    resumed = _trainer(*_setup()[:2])
    logs_resumed = resumed.run(3, ev, eval_every=2, engine="rounds",
                               resume=ckpt.load(p))
    # round 1 is a non-eval round: its acc is round 0's evaluated acc
    assert logs_straight[0].acc > 0.0
    assert logs_resumed[0].round == 1
    assert logs_resumed[0].acc == logs_straight[1].acc == logs_straight[0].acc
    assert logs_resumed[-1].acc == logs_straight[-1].acc


def test_resume_rejected_for_async():
    adapter, clients, ev = _setup()
    tr = _trainer(adapter, clients)
    with pytest.raises(ValueError, match="async"):
        tr.run(2, ev, engine="async", resume={"round": 1, "clock": 0.0,
                                              "trainer": tr.save_state()})


def test_async_envelope_rejected_by_sync_engines(tmp_path):
    """An async-written envelope counts merges, not rounds, and packs no
    participant rng — resuming it under rounds/events must raise instead of
    silently replaying round-0 draws at a bogus round cursor."""
    from repro.fed.engine import save_train_state

    p = os.path.join(str(tmp_path), "async.npz")
    adapter, clients, ev = _setup()
    tr = _trainer(adapter, clients)
    save_train_state(p, tr, round_=5, clock=10.0, engine="async")
    for engine in ("rounds", "events"):
        fresh = _trainer(*_setup()[:2])
        with pytest.raises(ValueError, match="engine"):
            fresh.run(6, ev, engine=engine, resume=ckpt.load(p))


def test_trainer_key_round_trips(tmp_path):
    adapter, clients, _ = _setup()
    tr = _trainer(adapter, clients)
    tr._next_key()
    state = tr.save_state()
    other = _trainer(*_setup()[:2])
    other.load_state(jax.tree.map(np.asarray, state))
    np.testing.assert_array_equal(np.asarray(tr.key), np.asarray(other.key))
    k1, k2 = jax.random.split(jnp.asarray(tr.key)), jax.random.split(other.key)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
