import os

# Smoke tests and benches see ONE device; only the dry-run forces 512
# (repro.launch.dryrun sets XLA_FLAGS itself, in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
