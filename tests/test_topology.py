"""Offload-topology plane: server-mode bit-equivalence, per-link pricing,
pairing execution equivalence, and checkpoint resume with pairing state.

The pre-refactor equivalence contract is pinned twice: tests/test_api.py's
golden test compares the spec path against commit f781a4b's direct wiring
(dtfl+fedavg x rounds+events), and here ``topology=server`` is compared
field-for-field against the topology-free default path."""
import numpy as np
import pytest

from repro.api import (DataSpec, ExperimentSpec, ModelSpec, SpecError,
                       TrainerSpec)
from repro.core import timemodel, topology
from repro.core.topology import SERVER, Assignment, OffloadTopology


def _tiny_spec(**over):
    spec = ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=4, samples=128, batch_size=16, iid=True,
                      eval_size=128),
        rounds=2)
    return spec.with_overrides(over) if over else spec


def _log_tuple(lg):
    return (lg.round, lg.clock, lg.acc, lg.assignment, lg.straggler,
            lg.uplink_bytes, lg.hosts)


def _params_equal(a, b) -> bool:
    import jax

    same = jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)
    return all(jax.tree.leaves(same))


def _params_close(a, b, atol=2e-4, rtol=1e-3):
    """Loop vs cohort tolerance — XLA schedules the planes differently, so
    they agree to numerics, not bitwise (same bound as tests/test_cohort.py)."""
    import jax

    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# time model: per-link / far-profile pricing
# ---------------------------------------------------------------------------

def _costs():
    from repro.configs.resnet_cifar import RESNET110

    return timemodel.resnet_tier_costs(RESNET110, 32)


def test_simulate_times_server_only_reduces_to_legacy():
    """An all-server topology prices bit-identically to the legacy batch
    call with n_sharing=len(participants) — the refactor's core contract."""
    costs = _costs()
    parts = [0, 1, 2, 3, 4]
    profs = [timemodel.PAPER_PROFILES[i % len(timemodel.PAPER_PROFILES)]
             for i in parts]
    tiers = np.array([6, 4, 3, 1, 0])
    nb = np.array([4, 7, 4, 9, 3])
    topo = OffloadTopology({k: Assignment(int(tiers[i]), SERVER)
                            for i, k in enumerate(parts)})
    got = topology.simulate_times(costs, topo, parts, profs, nb)
    want = timemodel.simulate_client_times_batch(
        costs, tiers, np.array([p.flops for p in profs]),
        np.array([p.bytes_per_s for p in profs]), nb,
        n_sharing=len(parts))
    for k in ("client", "comm", "server", "total"):
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_far_profile_and_link_override_scalar():
    costs = _costs()
    guest = timemodel.ResourceProfile(cpus=0.2, mbps=30)
    host = timemodel.ResourceProfile(cpus=4.0, mbps=100)
    tier, nb = 2, 5
    t = timemodel.simulate_client_times(
        costs, tier, guest, nb, far_profile=host,
        link_bytes_per_s=min(guest.bytes_per_s, host.bytes_per_s))
    comm_bytes = costs.d_size(tier, nb) * nb
    assert t["comm"] == pytest.approx(comm_bytes / guest.bytes_per_s)
    assert t["server"] == pytest.approx(
        costs.server_flops[tier] * nb / host.flops)
    assert t["total"] == pytest.approx(
        max(t["client"] + t["comm"], t["server"] + t["comm"]))
    # defaults unchanged: no overrides == the legacy call
    legacy = timemodel.simulate_client_times(costs, tier, guest, nb,
                                             n_sharing=3)
    relegacy = timemodel.simulate_client_times(costs, tier, guest, nb,
                                               n_sharing=3, far_profile=None,
                                               link_bytes_per_s=None)
    assert legacy == relegacy


def test_pairing_topology_prices_peer_links_and_hosting():
    """Guests pay the bottleneck link + the host's device speed; hosts pay
    their own round plus their guests' far-half work."""
    costs = _costs()
    fast = timemodel.ResourceProfile(cpus=4.0, mbps=100)
    slow = timemodel.ResourceProfile(cpus=0.2, mbps=10)
    parts = [0, 1]
    topo = OffloadTopology({0: Assignment(5, SERVER),    # host: on server
                            1: Assignment(1, 0)})        # guest: hosted by 0
    nb = np.array([4, 4])
    t = topology.simulate_times(costs, topo, parts, [fast, slow], nb)
    # guest wire is the min of the two ends
    assert t["link"][1] == pytest.approx(slow.bytes_per_s)
    # guest far half runs at the host's full speed
    assert t["server"][1] == pytest.approx(
        costs.server_flops[1] * 4 / fast.flops)
    # host total = its own Eq.-5 time + the guest's far-half work
    own = max(t["client"][0] + t["comm"][0], t["server"][0] + t["comm"][0])
    assert t["total"][0] == pytest.approx(own + t["server"][1])
    # the server now shares capacity over ONE client, not two
    assert t["server"][0] == pytest.approx(
        costs.server_flops[5] * 4 / timemodel.SERVER_FLOPS)


# ---------------------------------------------------------------------------
# topology=server is bit-identical to the default path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["dtfl", "fedavg"])
@pytest.mark.parametrize("engine", ["rounds", "events"])
def test_server_topology_bit_identical(method, engine):
    base = _tiny_spec(**{"trainer.method": method, "engine.name": engine})
    expl = _tiny_spec(**{"trainer.method": method, "engine.name": engine,
                         "trainer.topology": "server"})
    fed_a, fed_b = base.build(), expl.build()
    logs_a, logs_b = fed_a.run(), fed_b.run()
    assert [_log_tuple(l) for l in logs_a] == [_log_tuple(l) for l in logs_b]
    assert all(l.hosts is None for l in logs_a)
    assert _params_equal(fed_a.trainer.params, fed_b.trainer.params)


def test_server_mode_scheduler_observations_unchanged():
    """plan_round's server branch must feed the scheduler the exact legacy
    observation arrays (obs.nu = own uplink, obs.t = client + comm)."""
    fed = _tiny_spec().build()
    tr = fed.trainer
    participants = list(range(4))
    plan = tr.plan_round(0, participants)
    assert plan.topology is not None and plan.topology.is_server_only
    tiers = np.array([plan.assign[k] for k in participants])
    profs = [tr.env.profile(k) for k in participants]
    nb = np.array([tr.clients[k].n_batches for k in participants])
    want = timemodel.simulate_client_times_batch(
        tr.costs, tiers, np.array([p.flops for p in profs]),
        np.array([p.bytes_per_s for p in profs]), nb,
        server_flops=tr.server_flops, n_sharing=len(participants),
        wires=tr.wires)
    np.testing.assert_array_equal(plan.times, want["total"])
    np.testing.assert_array_equal(plan.obs["t"], want["client"] + want["comm"])
    np.testing.assert_array_equal(
        plan.obs["nu"], np.array([p.bytes_per_s for p in profs]))


# ---------------------------------------------------------------------------
# pairing mode: exec-plane equivalence, resume, spec surface
# ---------------------------------------------------------------------------

def _pairing_spec(**over):
    spec = ExperimentSpec(
        model=ModelSpec(cost_model="resnet-110"),
        data=DataSpec(clients=6, samples=192, batch_size=16, iid=True,
                      eval_size=128),
        trainer=TrainerSpec(method="dtfl", scheduler="pairing"),
        rounds=3)
    return spec.with_overrides(over) if over else spec


def test_pairing_loop_vs_cohort_equivalence():
    """Pairing changes scheduling + accounting, never the training math —
    the loop and cohort exec planes stay equivalent: identical logs
    (clocks, tiers, hosts, bytes) and params within the same numeric
    tolerance test_cohort.py pins for the server topology."""
    fed_l = _pairing_spec(**{"exec.mode": "loop"}).build()
    fed_c = _pairing_spec(**{"exec.mode": "cohort"}).build()
    logs_l, logs_c = fed_l.run(), fed_c.run()
    assert [_log_tuple(l) for l in logs_l] == [_log_tuple(l) for l in logs_c]
    assert any(lg.hosts for lg in logs_l), "pairing must activate"
    _params_close(fed_l.trainer.params, fed_c.trainer.params)


def test_pairing_checkpoint_resume_carries_assignment(tmp_path):
    path = str(tmp_path / "state.npz")
    full = _pairing_spec(rounds=4).build()
    full_logs = full.run()
    ck = _pairing_spec(**{"rounds": 2, "checkpoint.path": path,
                          "checkpoint.every": 2}).build()
    ck.run()
    saved_hosts = dict(ck.trainer.sched.last_hosts)
    assert saved_hosts, "pairing must have activated before the checkpoint"
    rest = _pairing_spec(**{"rounds": 4, "checkpoint.resume": path}).build()
    # the envelope carries the guest->host map and load_state restores it
    # (Federation.run() applies the same load before its first round)
    from repro import checkpoint as ckpt

    rest.trainer.load_state(ckpt.load(path)["trainer"])
    assert rest.trainer.sched.last_hosts == saved_hosts
    rest_logs = rest.run()
    tail = full_logs[2:]
    assert [l.round for l in rest_logs] == [l.round for l in tail]
    for a, b in zip(rest_logs, tail):
        assert (a.clock, a.acc, a.straggler, a.assignment, a.hosts) == (
            b.clock, b.acc, b.straggler, b.assignment, b.hosts)


def test_pairing_spec_surface():
    fed = _pairing_spec().build()
    assert fed.trainer.topology == "pairing"
    assert fed.spec.trainer.topology == "pairing"
    assert getattr(fed.trainer.sched, "provides_hosts", False)


def test_nonsplit_trainers_reject_pairing():
    """Satellite regression: non-split trainers reject scheduler=pairing at
    spec time with the legal choices listed."""
    with pytest.raises(SpecError, match="tier-scheduling"):
        ExperimentSpec(trainer=TrainerSpec(method="fedavg",
                                           scheduler="pairing"))
    with pytest.raises(SpecError, match="tier-scheduling"):
        ExperimentSpec(trainer=TrainerSpec(method="splitfed",
                                           topology="pairing"))
    # direct ctor misuse (bypassing the spec layer) also fails loudly
    with pytest.raises(ValueError, match="pairing"):
        TrainerSpec(method="dtfl", scheduler=3, topology="pairing")


def test_topology_cli_flag_roundtrip():
    from repro.launch.train import build_parser, spec_from_args

    spec = spec_from_args(build_parser().parse_args(
        ["--topology", "pairing", "--rounds", "1"]))
    assert spec.trainer.topology == "pairing"
    assert spec.trainer.scheduler == "pairing"
    with pytest.raises(SystemExit):
        build_parser().parse_args(["--topology", "mesh"])
