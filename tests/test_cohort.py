"""Tier-cohort engine: cohort-mode vs sequential-mode equivalence.

The vectorized round engine (fed/cohort.py) must produce numerically close
global params / aux heads and IDENTICAL scheduler observations to the
per-client sequential loop, including on ragged cohorts (unequal batch
counts) and shape-bucketed cohorts (a client with fewer samples than one
batch).
"""
import jax
import numpy as np
import pytest

from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.data.pipeline import ClientDataset
from repro.data.synthetic import ClassImageTask
from repro.fed import DTFLTrainer, FedAvgTrainer, HeteroEnv, ResNetAdapter, SimClient
from repro.fed import cohort as cohort_engine


def build_clients(sizes, batch=16):
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, sum(sizes))
    clients, off = [], 0
    for i, s in enumerate(sizes):
        idx = np.arange(off, off + s)
        off += s
        clients.append(SimClient(i, ClientDataset(task, labels, idx, batch), None))
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)
    return adapter, clients


def assert_trees_close(a, b, atol=2e-4, rtol=1e-3):
    ja, jb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(ja) == len(jb)
    for x, y in zip(ja, jb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol, rtol=rtol)


def run_both(adapter, clients, *, scheduler="dynamic", rounds=2):
    trainers = []
    for exec_plan in ("loop", "cohort"):
        tr = DTFLTrainer(
            adapter, clients, HeteroEnv(len(clients), seed=0), optim.adam(1e-3),
            seed=0, scheduler=scheduler, exec_plan=exec_plan,
        )
        trainers.append(tr)
    seq, coh = trainers
    parts = list(range(len(clients)))
    for r in range(rounds):
        s1, a1 = seq.train_round(r, parts)
        s2, a2 = coh.train_round(r, parts)
        assert a1 == a2, f"round {r}: tier assignments diverged"
        assert s1 == pytest.approx(s2, rel=1e-12)
    return seq, coh


def test_cohort_equals_sequential():
    adapter, clients = build_clients([64, 64, 48, 32])
    seq, coh = run_both(adapter, clients)
    assert_trees_close(seq.params, coh.params)
    for m in seq.aux:
        assert_trees_close(seq.aux[m], coh.aux[m])


def test_cohort_scheduler_observations_identical():
    adapter, clients = build_clients([64, 64, 48, 32])
    seq, coh = run_both(adapter, clients)
    for c1, c2 in zip(seq.sched.clients, coh.sched.clients):
        assert c1.tier == c2.tier
        assert c1.last_obs_tier == c2.last_obs_tier
        assert c1.nu == c2.nu and c1.n_batches == c2.n_batches
        assert set(c1.ema) == set(c2.ema)
        for m in c1.ema:
            assert c1.ema[m].value == pytest.approx(c2.ema[m].value, rel=1e-12)


def test_ragged_cohort_equals_sequential():
    """Unequal n_batches (4/3/1/6) in ONE static tier -> padded+masked scan."""
    adapter, clients = build_clients([64, 48, 16, 96])
    assert sorted(c.n_batches for c in clients) == [1, 3, 4, 6]
    seq, coh = run_both(adapter, clients, scheduler=1)
    assert_trees_close(seq.params, coh.params)


def test_short_batch_client_shares_shape_bucket():
    """A client with fewer samples than one batch pads to the FIXED batch
    shape (mask-weighted loss, data/pipeline.py), so it shares the tier's
    cohort instead of forcing its own (tier, shape) compile — and still
    matches the loop."""
    adapter, clients = build_clients([64, 48, 10])
    b0 = next(clients[2].dataset.epoch(0))
    assert b0["images"].shape[0] == 16 and b0["mask"].sum() == 10
    cohorts = cohort_engine.build_cohorts(
        clients, [0, 1, 2], {0: 1, 1: 1, 2: 1}, r=0, local_epochs=1
    )
    assert len(cohorts) == 1  # one shape bucket -> one compiled program
    assert cohorts[0].size == 3
    seq, coh = run_both(adapter, clients, scheduler=1)
    # looser atol: adam's 1/(sqrt(v)+eps) amplifies reduction-order noise on
    # near-zero grads, so a few elements drift ~1e-3 over two rounds
    assert_trees_close(seq.params, coh.params, atol=2e-3, rtol=1e-2)


def test_cohort_mask_semantics():
    """Padded steps are masked out: mask rows beyond a client's real step
    count are False and padded batches are zero-filled."""
    adapter, clients = build_clients([64, 32], batch=16)  # 4 vs 2 batches
    (co,) = cohort_engine.build_cohorts(clients, [0, 1], {0: 0, 1: 0}, 0, 1)
    assert co.mask.shape == (4, 2)
    assert co.mask[:, 0].all() and co.mask[:2, 1].all() and not co.mask[2:, 1].any()
    assert co.batches["images"].shape[:2] == (4, 2)
    np.testing.assert_array_equal(co.batches["images"][2:, 1], 0.0)


def test_baseline_cohort_equals_sequential():
    adapter, clients = build_clients([64, 48, 96])
    trainers = []
    for exec_plan in ("loop", "cohort"):
        tr = FedAvgTrainer(
            adapter, clients, HeteroEnv(len(clients), seed=0), optim.adam(1e-3),
            seed=0, exec_plan=exec_plan,
        )
        trainers.append(tr)
    seq, coh = trainers
    for r in range(2):
        s1 = seq.train_round(r, [0, 1, 2])
        s2 = coh.train_round(r, [0, 1, 2])
        assert s1 == pytest.approx(s2, rel=1e-12)
    assert_trees_close(seq.params, coh.params)
