"""Per-arch smoke tests (reduced configs, one forward/train step, no NaNs)
+ decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import local_loss
from repro.models import model as M


def make_batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 2)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.frontend != "none":
        batch["frontend"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_frontend or cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_smoke(arch, key):
    cfg = get_config(arch).reduced()
    params = M.init(key, cfg)
    batch = make_batch(cfg, key)
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch, key):
    """One full train step on the reduced config: finite loss, params move."""
    cfg = get_config(arch).reduced()
    params = M.init(key, cfg)
    opt = optim.adam(1e-3)
    step = jax.jit(local_loss.make_full_train_step(cfg, opt))
    batch = make_batch(cfg, key)
    p2, _, loss = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(loss)), arch
    moved = jax.tree.map(lambda a, b: not jnp.array_equal(a, b), params, p2)
    assert any(jax.tree.leaves(moved)), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_dtfl_step_smoke(arch, key):
    cfg = get_config(arch).reduced().replace(tie_embeddings=False, n_modules=3)
    params = M.init(key, cfg)
    opt = optim.adam(1e-3)
    state = local_loss.init_tier_state(key, cfg, params, 1, opt)
    step = jax.jit(local_loss.make_dtfl_train_step(cfg, opt))
    batch = make_batch(cfg, key)
    state, met = step(state, batch)
    assert bool(jnp.isfinite(met.client_loss)) and bool(jnp.isfinite(met.server_loss))


def _fill_cross_cache(cfg, params, batch, cache):
    from repro.models.layers import cdtype

    enc = M.encode(params, cfg, batch)
    dt = cdtype(cfg)
    hd = cfg.resolved_head_dim
    B = enc.shape[0]
    xk = jnp.stack([(enc.astype(dt) @ params["blocks"]["xattn"]["wk"][i].astype(dt))
                    .reshape(B, -1, cfg.n_kv_heads, hd) for i in range(cfg.n_layers)])
    xv = jnp.stack([(enc.astype(dt) @ params["blocks"]["xattn"]["wv"][i].astype(dt))
                    .reshape(B, -1, cfg.n_kv_heads, hd) for i in range(cfg.n_layers)])
    cache["layers"]["xk"], cache["layers"]["xv"] = xk, xv
    return cache


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch, key):
    cfg = get_config(arch).reduced().replace(dtype="float32")
    if cfg.n_experts:
        # pin capacity so no token is ever dropped in either path
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    params = M.init(key, cfg)
    B, S = 2, 16
    batch = make_batch(cfg, key, B, S)
    logits, _ = M.forward(params, cfg, batch)
    cache = M.init_cache(cfg, B, S)
    if cfg.family == "encdec":
        cache = _fill_cross_cache(cfg, params, batch, cache)
    step = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, batch["tokens"][:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    if cfg.family == "vlm":
        pytest.skip("vlm decode has no frontend fusion (prefill-only path)")
    assert jnp.allclose(dec, logits, atol=2e-4), float(jnp.abs(dec - logits).max())


def test_sliding_window_decode_ring_buffer(key):
    """Ring-buffer decode == full-cache decode restricted to the window."""
    cfg = get_config("hymba-1.5b").reduced().replace(dtype="float32", window=8)
    params = M.init(key, cfg)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    # windowed forward (train path applies cfg.window)
    logits, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfg, B, S)  # W = min(S, window) = 8 ring
    assert cache["layers"]["k"].shape[2] == 8
    step = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, toks[:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    assert jnp.allclose(dec, logits, atol=2e-4), float(jnp.abs(dec - logits).max())


def test_param_count_analytic_matches_init(key):
    for arch in ("yi-6b", "deepseek-moe-16b", "xlstm-350m"):
        cfg = get_config(arch).reduced()
        params = M.init(key, cfg)
        real = sum(a.size for a in jax.tree.leaves(params) if a.dtype != bool)
        assert M.count_params_analytic(cfg) == real, arch


def test_moe_active_params_less_than_total():
    cfg = get_config("deepseek-moe-16b")
    assert M.count_params_analytic(cfg, active_only=True) < M.count_params_analytic(cfg)
