"""End-to-end system behaviour: full federated runs on the paper's CNN path
and the transformer path, plus the headline DTFL-vs-FedAvg time claim."""
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.configs.resnet_cifar import RESNET56
from repro.data.partition import dirichlet_partition
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import (DTFLTrainer, FedAvgTrainer, HeteroEnv, ResNetAdapter,
                       SimClient, TransformerAdapter, TRAINERS)


@pytest.fixture(scope="module")
def image_setup():
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, 1500)
    parts = dirichlet_partition(labels, 5, 0.5, seed=1)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(5)]
    return cfg, clients, make_eval_batch(task, 256)


def test_dtfl_learns(image_setup):
    cfg, clients, ev = image_setup
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)
    tr = DTFLTrainer(adapter, clients, HeteroEnv(5, seed=0), optim.adam(1e-3), seed=0)
    logs = tr.run(6, ev)
    assert logs[-1].acc > logs[0].acc
    assert logs[-1].acc > 0.4
    assert logs[-1].clock > 0


@pytest.mark.parametrize("method", ["fedavg", "fedyogi", "splitfed", "fedgkt"])
def test_baselines_learn(image_setup, method):
    cfg, clients, ev = image_setup
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)
    lr = 5e-3 if method == "fedyogi" else 1e-3
    tr = TRAINERS[method](adapter, clients, HeteroEnv(5, seed=0), optim.adam(lr), seed=0)
    logs = tr.run(5, ev)
    assert logs[-1].acc > logs[0].acc, method


def test_dtfl_round_time_beats_fedavg(image_setup):
    """The paper's headline: on a heterogeneous pool, DTFL's straggler-bounded
    time is well below FedAvg's (full model on the weakest client). Priced on
    the FULL ResNet-110 cost table — the paper's large-model regime (on small
    models the offload/comm trade is a wash, consistent with the paper's
    framing that DTFL targets LARGE models)."""
    from repro.configs.resnet_cifar import RESNET110

    cfg, clients, ev = image_setup
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET110)
    dtfl = DTFLTrainer(adapter, clients, HeteroEnv(5, seed=0), optim.adam(1e-3), seed=0)
    fedavg = FedAvgTrainer(adapter, clients, HeteroEnv(5, seed=0), optim.adam(1e-3), seed=0)
    l1 = dtfl.run(4, ev)
    l2 = fedavg.run(4, ev)
    assert l1[-1].clock < l2[-1].clock
    assert l1[-1].straggler < l2[-1].straggler


def test_dtfl_transformer_path():
    from repro.launch.train import SeqClientDataset
    from repro.data.synthetic import SeqTask

    cfg = get_config("smollm-360m").reduced()
    adapter = TransformerAdapter(cfg, seq_len=32, cost_cfg=get_config("smollm-360m"))
    task = SeqTask(vocab=adapter.cfg.vocab)
    clients = [SimClient(i, SeqClientDataset(task, 2, 4, 32, i), None) for i in range(3)]
    ev = next(task.batches(8, 32, 1, seed=99))
    tr = DTFLTrainer(adapter, clients, HeteroEnv(3, seed=0), optim.adam(2e-3), seed=0)
    logs = tr.run(5, ev)
    assert logs[-1].acc >= logs[0].acc


def test_dynamic_scheduler_beats_static_worst_tier(image_setup):
    cfg, clients, ev = image_setup
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)
    dyn = DTFLTrainer(adapter, clients, HeteroEnv(5, seed=0), optim.adam(1e-3),
                      scheduler="dynamic", seed=0)
    static_hi = DTFLTrainer(adapter, clients, HeteroEnv(5, seed=0), optim.adam(1e-3),
                            scheduler=adapter.n_tiers - 1, seed=0)
    l_dyn = dyn.run(4, ev)
    l_hi = static_hi.run(4, ev)
    assert l_dyn[-1].straggler <= l_hi[-1].straggler * 1.05
