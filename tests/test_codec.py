"""Communication plane: codec round-trips, wire accounting, identity
bit-exactness, cross-plane equivalence, and error-feedback resume.

Key contracts pinned here:
  * ``--codec identity`` IS the uncompressed path — bit-for-bit identical
    params, scheduler observations, and round clocks on DTFL + FedAvg,
    across exec planes and engines;
  * lossy codecs agree between the loop and cohort planes to quantization-
    step tolerance (quantization is discontinuous: a 1-ulp vmap reduction
    difference may flip a bucket, so exact equality is not the contract);
  * top-k's client-held error-feedback residuals ride the checkpoint
    envelope and resume bit-deterministically;
  * codec-true wire bytes flow into the scheduler profile, the simulated
    clocks, and RoundLog.uplink_bytes.
"""
import os

import jax
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.core import timemodel
from repro.core.codec import (Bf16Codec, IdentityCodec, Int8Codec, TopKCodec,
                              make_codec, wire_sizes)
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import (DTFLTrainer, FedAvgTrainer, HeteroEnv, ResNetAdapter,
                       SimClient, TRAINERS)

jnp = jax.numpy


# ---------------------------------------------------------------------------
# codec unit behavior
# ---------------------------------------------------------------------------

def test_make_codec_specs():
    assert make_codec(None).is_identity
    assert make_codec("identity").is_identity
    assert isinstance(make_codec("bf16"), Bf16Codec)
    assert isinstance(make_codec("int8"), Int8Codec)
    tk = make_codec("topk0.05")
    assert isinstance(tk, TopKCodec) and tk.frac == 0.05
    with pytest.raises(ValueError):
        make_codec("gzip")
    with pytest.raises(ValueError):
        make_codec("topk1.5")


def test_identity_tree_rt_is_structural_noop():
    tree = {"a": jnp.ones((3, 4)), "b": (jnp.zeros(2),)}
    assert IdentityCodec().tree_rt(tree) is tree


def test_bf16_roundtrip_error_bound(key):
    x = jax.random.normal(key, (64, 32))
    y = Bf16Codec().rt(x)
    # bf16 has 8 mantissa bits -> relative error <= 2^-8
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=2 ** -8, atol=0)


def test_int8_roundtrip_error_bound(key):
    x = jax.random.normal(key, (128, 16))
    y = Int8Codec().rt(x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(y - x))) <= 0.5 * scale + 1e-7


def test_topk_keeps_exactly_k(key):
    x = jax.random.normal(key, (40, 10))
    y = TopKCodec(0.1).rt(x)
    kept = np.flatnonzero(np.asarray(y).ravel())
    assert len(kept) == 40  # ceil(0.1 * 400)
    xa = np.abs(np.asarray(x).ravel())
    assert xa[kept].min() >= np.sort(xa)[-40] - 1e-12
    np.testing.assert_array_equal(np.asarray(y).ravel()[kept],
                                  np.asarray(x).ravel()[kept])


def test_topk_error_feedback_transmits_everything_eventually(key):
    """With EF, repeatedly uploading the SAME tensor drains the residual:
    the un-sent mass re-enters until every coordinate has been sent."""
    codec = TopKCodec(0.25)
    x = jax.random.normal(key, (16,))
    e = jnp.zeros_like(x)
    received = jnp.zeros_like(x)
    for _ in range(8):
        y, e = codec.rt_ef(x, e)
        received = received + y
    # total received + residual == total uploaded (conservation)
    np.testing.assert_allclose(np.asarray(received + e), np.asarray(8 * x),
                               atol=1e-5)
    # the residual stays bounded (coords queue, they don't leak): a coord
    # can transiently exceed max|x| while waiting to enter the top-k, but
    # never grows unboundedly with the number of rounds
    assert float(jnp.max(jnp.abs(e))) <= 8.0 * float(jnp.max(jnp.abs(x)))
    assert np.isfinite(np.asarray(e)).all()


def test_int_leaves_pass_through():
    x = jnp.arange(10, dtype=jnp.int32)
    for c in (Bf16Codec(), Int8Codec(), TopKCodec(0.5)):
        assert c.rt(x) is x


def test_nbytes_accounting():
    n = np.array([1000.0, 10.0])
    np.testing.assert_array_equal(IdentityCodec().nbytes(n), [4000.0, 40.0])
    np.testing.assert_array_equal(Bf16Codec().nbytes(n), [2000.0, 20.0])
    np.testing.assert_array_equal(Int8Codec().nbytes(n), [1004.0, 14.0])
    np.testing.assert_array_equal(TopKCodec(0.05).nbytes(n), [400.0, 8.0])
    # top-k's DOWNLOAD wire is dense (identity transform, fp32 pricing)
    np.testing.assert_array_equal(TopKCodec(0.05).down_nbytes(n), [4000.0, 40.0])
    x = jnp.arange(8.0)
    assert TopKCodec(0.05).down_rt(x) is x
    assert (np.asarray(Int8Codec().down_rt(x))
            == np.asarray(Int8Codec().rt(x))).all()


def test_wire_sizes_identity_matches_legacy_accounting():
    costs = timemodel.resnet_tier_costs(RESNET56, 32)
    w = wire_sizes(costs)  # identity
    np.testing.assert_array_equal(w.z_bytes, costs.z_bytes)
    np.testing.assert_array_equal(w.down_bytes, costs.client_param_bytes)
    np.testing.assert_array_equal(w.up_bytes, np.zeros_like(w.up_bytes))
    assert w.full_down == w.full_up == costs.full_param_bytes
    # compressed codecs price all three wires from element counts
    w8 = wire_sizes(costs, "int8")
    assert (w8.z_bytes < w.z_bytes).all()
    assert (w8.up_bytes > 0).all()


# ---------------------------------------------------------------------------
# trainer-level contracts
# ---------------------------------------------------------------------------

def _build(sizes=(64, 64, 48), batch=16, seed=0):
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, sum(sizes))
    clients, off = [], 0
    for i, s in enumerate(sizes):
        clients.append(
            SimClient(i, ClientDataset(task, labels, np.arange(off, off + s), batch), None))
        off += s
    return ResNetAdapter(cfg, cost_cfg=RESNET56), clients, task


def _dtfl(adapter, clients, codec=None, exec_plan=None, seed=0):
    return DTFLTrainer(adapter, clients, HeteroEnv(len(clients), seed=seed),
                       optim.adam(1e-3), seed=seed, codec=codec,
                       exec_plan=exec_plan)


def _assert_trees(a, b, *, exact=False, atol=5e-3, rtol=5e-3):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=atol, rtol=rtol)


@pytest.mark.parametrize("cls", [DTFLTrainer, FedAvgTrainer])
def test_identity_codec_bit_equals_default(cls):
    """--codec identity must be bit-for-bit the pre-codec path: params,
    clocks, assignments, scheduler observations."""
    adapter, clients, _ = _build()
    kw = {} if cls is DTFLTrainer else {}
    a = cls(adapter, clients, HeteroEnv(3, seed=0), optim.adam(1e-3), seed=0, **kw)
    b = cls(adapter, clients, HeteroEnv(3, seed=0), optim.adam(1e-3), seed=0,
            codec="identity", **kw)
    parts = [0, 1, 2]
    for r in range(2):
        ra, rb = a.train_round(r, parts), b.train_round(r, parts)
        if isinstance(ra, tuple):
            assert ra[0] == rb[0] and ra[1] == rb[1]
        else:
            assert ra == rb
    _assert_trees(a.params, b.params, exact=True)
    assert a.last_uplink_bytes == b.last_uplink_bytes
    if cls is DTFLTrainer:
        for c1, c2 in zip(a.sched.clients, b.sched.clients):
            assert c1.tier == c2.tier and set(c1.ema) == set(c2.ema)
            for m in c1.ema:
                assert c1.ema[m].value == c2.ema[m].value


def test_identity_codec_events_engine_bit_equal():
    adapter, clients, task = _build()
    ev = make_eval_batch(task, 64)
    a = _dtfl(*_build()[:2])
    b = _dtfl(*_build()[:2], codec="identity")
    la = a.run(2, ev, engine="events")
    lb = b.run(2, ev, engine="events")
    assert [l.clock for l in la] == [l.clock for l in lb]
    assert [l.uplink_bytes for l in la] == [l.uplink_bytes for l in lb]
    _assert_trees(a.params, b.params, exact=True)


@pytest.mark.parametrize("codec", ["int8", "topk0.1"])
def test_codec_loop_equals_cohort_to_quant_tolerance(codec):
    adapter, clients, _ = _build()
    lo = _dtfl(adapter, clients, codec=codec, exec_plan="loop")
    co = _dtfl(adapter, clients, codec=codec, exec_plan="cohort")
    parts = [0, 1, 2]
    for r in range(2):
        _, a1 = lo.train_round(r, parts)
        _, a2 = co.train_round(r, parts)
        assert a1 == a2
    _assert_trees(lo.params, co.params)
    # scheduler observations identical (time model is plane-independent)
    for c1, c2 in zip(lo.sched.clients, co.sched.clients):
        assert c1.tier == c2.tier
        for m in c1.ema:
            assert c1.ema[m].value == pytest.approx(c2.ema[m].value, rel=1e-12)


def test_codec_changes_comm_times_and_uplink_bytes():
    """int8 must shrink both the simulated comm times (the scheduler's
    straggler clock) and the reported uplink bytes vs identity."""
    adapter, clients, _ = _build()
    ident = _dtfl(adapter, clients)
    quant = _dtfl(adapter, clients, codec="int8")
    s_i, _ = ident.train_round(0, [0, 1, 2])
    s_q, _ = quant.train_round(0, [0, 1, 2])
    assert quant.last_uplink_bytes < 0.5 * ident.last_uplink_bytes
    assert s_q < s_i  # comm share of Eq. 5 shrinks


def test_uplink_bytes_logged_per_round():
    adapter, clients, task = _build()
    ev = make_eval_batch(task, 64)
    tr = _dtfl(adapter, clients, codec="int8")
    logs = tr.run(2, ev, engine="rounds")
    assert all(l.uplink_bytes > 0 for l in logs)
    assert logs[0].uplink_bytes == pytest.approx(tr.last_uplink_bytes)


def test_topk_ef_state_resumes_bit_deterministically(tmp_path):
    """Error-feedback residuals ride the checkpoint envelope: straight run
    == save@2 -> fresh process -> resume -> continue, bit for bit."""
    p = os.path.join(str(tmp_path), "state.npz")
    adapter, clients, task = _build()
    ev = make_eval_batch(task, 64)

    straight = _dtfl(*_build()[:2], codec="topk0.1")
    straight.run(4, ev, engine="rounds")

    first = _dtfl(*_build()[:2], codec="topk0.1")
    first.run(2, ev, engine="rounds", checkpoint_path=p, checkpoint_every=2)
    resumed = _dtfl(*_build()[:2], codec="topk0.1")
    logs = resumed.run(4, ev, engine="rounds", resume=ckpt.load(p))

    assert [l.round for l in logs] == [2, 3]
    _assert_trees(straight.params, resumed.params, exact=True)
    assert sorted(straight._ef) == sorted(resumed._ef)
    for cid in straight._ef:
        assert straight._ef[cid]["tier"] == resumed._ef[cid]["tier"]
        _assert_trees(straight._ef[cid]["c"], resumed._ef[cid]["c"], exact=True)
        _assert_trees(straight._ef[cid]["a"], resumed._ef[cid]["a"], exact=True)


def test_topk_does_not_sparsify_the_global_model():
    """Regression: sparsifying the DOWNLOAD wire zeroed ~(1-frac) of the
    aggregated global every round (client-held EF can't compensate a
    truncated broadcast). With a dense download, the global stays dense."""
    adapter, clients, _ = _build()
    tr = FedAvgTrainer(adapter, clients, HeteroEnv(3, seed=0), optim.adam(1e-3),
                       seed=0, codec="topk0.05")
    tr.train_round(0, [0, 1, 2])
    flat = np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(tr.params)])
    assert np.mean(flat == 0.0) < 0.1, f"global went sparse: {np.mean(flat == 0.0):.2%}"


@pytest.mark.parametrize("method", ["splitfed", "fedgkt"])
def test_codec_unsupported_trainers_reject(method):
    adapter, clients, _ = _build()
    with pytest.raises(ValueError, match="codec"):
        TRAINERS[method](adapter, clients, HeteroEnv(3, seed=0),
                         optim.adam(1e-3), seed=0, codec="int8")


def test_fedavg_int8_runs_and_shrinks_wires():
    adapter, clients, _ = _build()
    f_i = FedAvgTrainer(adapter, clients, HeteroEnv(3, seed=0), optim.adam(1e-3), seed=0)
    f_q = FedAvgTrainer(adapter, clients, HeteroEnv(3, seed=0), optim.adam(1e-3),
                        seed=0, codec="int8")
    s_i = f_i.train_round(0, [0, 1, 2])
    s_q = f_q.train_round(0, [0, 1, 2])
    assert f_q.last_uplink_bytes < 0.5 * f_i.last_uplink_bytes
    assert s_q < s_i
    _assert_trees(f_i.params, f_q.params, atol=0.05, rtol=0.1)  # same ballpark
