"""Optimizers, data pipeline, checkpointing, privacy substrates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, st

from repro import checkpoint as ckpt
from repro import optim
from repro.data.partition import dirichlet_partition, iid_partition, label_histogram
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import DATASETS, ClassImageTask, SeqTask
from repro.privacy import dcor, patch_shuffle


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", [optim.sgd(0.1), optim.sgd(0.05, momentum=0.9),
                                 optim.adam(0.05), optim.yogi(0.05)])
def test_optimizer_minimizes_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_set_lr():
    opt = optim.adam(1e-3)
    s = opt.init({"w": jnp.zeros(2)})
    s = optim.set_lr(s, 5e-4)
    assert optim.get_lr(s) == pytest.approx(5e-4)


def test_plateau_schedule():
    sched = optim.PlateauSchedule(factor=0.9, patience=2)
    lr = 1.0
    lr = sched.step(0.5, lr)   # improves
    lr = sched.step(0.5, lr)   # stall 1
    lr = sched.step(0.5, lr)   # stall 2 -> cut
    assert lr == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_partitions_cover_and_disjoint():
    labels = np.random.default_rng(0).integers(0, 10, 1000)
    for parts in (iid_partition(labels, 7), dirichlet_partition(labels, 7, 0.5)):
        allidx = np.concatenate(parts)
        assert len(allidx) == 1000
        assert len(np.unique(allidx)) == 1000


def test_dirichlet_skew_exceeds_iid():
    labels = np.random.default_rng(0).integers(0, 10, 5000)
    h_iid = label_histogram(labels, iid_partition(labels, 10))
    h_dir = label_histogram(labels, dirichlet_partition(labels, 10, 0.5))
    cv = lambda h: float(np.std(h, 0).mean() / (np.mean(h) + 1e-9))
    assert cv(h_dir) > 2 * cv(h_iid)


def test_pipeline_deterministic():
    task = DATASETS["cifar10"]
    labels = np.random.default_rng(0).integers(0, 10, 200)
    ds = ClientDataset(task, labels, np.arange(200), 32, seed=5)
    b1 = list(ds.epoch(3))
    b2 = list(ds.epoch(3))
    assert all(np.array_equal(x["images"], y["images"]) for x, y in zip(b1, b2))
    assert ds.n_batches == len(b1)


def test_seqtask_learnable_structure():
    t = SeqTask(vocab=50)
    s = t.stream(1000, seed=0)
    # >=80% of transitions follow the deterministic rule
    a = t.__class__
    s2 = t.stream(1000, seed=0)
    assert np.array_equal(s, s2)


# ---------------------------------------------------------------------------
# checkpoint (property-based roundtrip)
# ---------------------------------------------------------------------------

leaf = st.sampled_from([np.float32, np.int32]).flatmap(
    lambda d: st.integers(0, 3).map(
        lambda nd: np.arange(int(np.prod([2] * nd)), dtype=d).reshape([2] * nd)
    )
)
trees = st.recursive(
    leaf,
    lambda children: st.one_of(
        st.dictionaries(st.sampled_from(list("abcde")), children, min_size=1, max_size=3),
        st.lists(children, min_size=1, max_size=3),
        st.tuples(children, children),
    ),
    max_leaves=8,
)


@given(tree=trees)
@settings(max_examples=40, deadline=None)
def test_checkpoint_roundtrip(tmp_path_factory, tree):
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.npz")
        ckpt.save(path, tree)
        back = ckpt.load(path)
    assert jax.tree.structure(tree) == jax.tree.structure(back)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# privacy
# ---------------------------------------------------------------------------

def test_dcor_bounds(key):
    x = jax.random.normal(key, (128, 32))
    assert 0.0 <= float(dcor(x, x)) <= 1.0 + 1e-5
    assert float(dcor(x, x)) > 0.99      # self-correlation ~1
    z = jax.random.normal(jax.random.PRNGKey(9), (128, 8))
    assert float(dcor(x, z)) < float(dcor(x, x))


@given(n=st.integers(2, 16), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_patch_shuffle_preserves_multiset(n, seed):
    z = jnp.arange(4 * 32.0).reshape(4, 32)
    out = patch_shuffle(jax.random.PRNGKey(seed), z, n_patches=n)
    np.testing.assert_allclose(np.sort(np.asarray(out), axis=1),
                               np.sort(np.asarray(z), axis=1))
