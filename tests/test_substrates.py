"""Optimizers, data pipeline, checkpointing, privacy substrates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, st

from repro import checkpoint as ckpt
from repro import optim
from repro.data.partition import dirichlet_partition, iid_partition, label_histogram
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import DATASETS, ClassImageTask, SeqTask
from repro.privacy import dcor, patch_shuffle


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", [optim.sgd(0.1), optim.sgd(0.05, momentum=0.9),
                                 optim.adam(0.05), optim.yogi(0.05)])
def test_optimizer_minimizes_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_set_lr():
    opt = optim.adam(1e-3)
    s = opt.init({"w": jnp.zeros(2)})
    s = optim.set_lr(s, 5e-4)
    assert optim.get_lr(s) == pytest.approx(5e-4)


def test_plateau_schedule():
    sched = optim.PlateauSchedule(factor=0.9, patience=2)
    lr = 1.0
    lr = sched.step(0.5, lr)   # improves
    lr = sched.step(0.5, lr)   # stall 1
    lr = sched.step(0.5, lr)   # stall 2 -> cut
    assert lr == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_partitions_cover_and_disjoint():
    labels = np.random.default_rng(0).integers(0, 10, 1000)
    for parts in (iid_partition(labels, 7), dirichlet_partition(labels, 7, 0.5)):
        allidx = np.concatenate(parts)
        assert len(allidx) == 1000
        assert len(np.unique(allidx)) == 1000


def test_dirichlet_skew_exceeds_iid():
    labels = np.random.default_rng(0).integers(0, 10, 5000)
    h_iid = label_histogram(labels, iid_partition(labels, 10))
    h_dir = label_histogram(labels, dirichlet_partition(labels, 10, 0.5))
    cv = lambda h: float(np.std(h, 0).mean() / (np.mean(h) + 1e-9))
    assert cv(h_dir) > 2 * cv(h_iid)


def test_dirichlet_retry_is_bounded_and_deterministic():
    """Regression: the min_size rejection loop used to be ``while True`` —
    with few samples / many clients it spun forever. Now: fast-fail on an
    unsatisfiable constraint, a clear error after max_tries, and identical
    partitions for seeds that pass on the first attempt."""
    labels = np.random.default_rng(0).integers(0, 10, 500)
    # unsatisfiable: 8 clients x min_size 2 > 4 samples
    with pytest.raises(ValueError, match="needs >= 16 samples"):
        dirichlet_partition(np.zeros(4, int), 8)
    # satisfiable-but-hard: bounded attempts, clear error (alpha tiny ->
    # nearly all mass on one client each class; min_size extreme)
    with pytest.raises(ValueError, match="after 3 attempts"):
        dirichlet_partition(labels, 10, alpha=0.01, min_size=40, max_tries=3)
    p1 = dirichlet_partition(labels, 5, seed=3)
    p2 = dirichlet_partition(labels, 5, seed=3)
    assert all(np.array_equal(a, b) for a, b in zip(p1, p2))


def test_partial_batch_pads_to_fixed_shape():
    """Regression: clients with < batch_size samples used to emit a
    variable-shaped batch (own cohort compile per odd shape). Now every
    batch has the fixed shape + a pad mask, and the masked loss equals the
    unpadded loss exactly."""
    task = DATASETS["cifar10"]
    labels = np.random.default_rng(0).integers(0, 10, 200)
    ds = ClientDataset(task, labels, np.arange(5), 32, seed=1)
    (b,) = list(ds.epoch(0))
    assert b["images"].shape[0] == 32 and b["labels"].shape == (32,)
    np.testing.assert_array_equal(b["mask"][:5], 1.0)
    np.testing.assert_array_equal(b["mask"][5:], 0.0)
    np.testing.assert_array_equal(b["images"][5:], 0.0)
    # masked xent == plain xent over the real rows only
    from repro.core.local_loss import token_xent

    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 10))
    lab = jnp.asarray(b["labels"])
    masked = token_xent(logits, lab, weight=jnp.asarray(b["mask"]))
    plain = token_xent(logits[:5], lab[:5])
    assert float(masked) == pytest.approx(float(plain), rel=1e-6)
    # ...and so does the KD loss (FedGKT's teacher/student terms)
    from repro.fed.base import kd_loss

    teacher = jax.random.normal(jax.random.PRNGKey(1), (32, 10))
    mkd = kd_loss(logits, teacher, weight=jnp.asarray(b["mask"]))
    pkd = kd_loss(logits[:5], teacher[:5])
    assert float(mkd) == pytest.approx(float(pkd), rel=1e-6)


def test_dirichlet_run_compiles_one_program_per_tier():
    """With fixed batch shapes, a Dirichlet-partitioned round builds
    O(n_tiers) cohorts — undersized clients share the tier bucket."""
    from repro.fed import cohort as cohort_engine
    from repro.fed.client import SimClient

    task = DATASETS["cifar10"]
    labels = np.random.default_rng(0).integers(0, 10, 300)
    parts = dirichlet_partition(labels, 8, 0.3, seed=2, min_size=1)
    assert min(len(p) for p in parts) < 32 <= max(len(p) for p in parts)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(8)]
    tier_of = {k: k % 3 for k in range(8)}   # 3 tiers in play
    cohorts = cohort_engine.build_cohorts(clients, list(range(8)), tier_of, 0, 1)
    assert len(cohorts) == len(set(tier_of.values()))


def test_pipeline_deterministic():
    task = DATASETS["cifar10"]
    labels = np.random.default_rng(0).integers(0, 10, 200)
    ds = ClientDataset(task, labels, np.arange(200), 32, seed=5)
    b1 = list(ds.epoch(3))
    b2 = list(ds.epoch(3))
    assert all(np.array_equal(x["images"], y["images"]) for x, y in zip(b1, b2))
    assert ds.n_batches == len(b1)


def test_seqtask_learnable_structure():
    t = SeqTask(vocab=50)
    s = t.stream(1000, seed=0)
    # >=80% of transitions follow the deterministic rule
    a = t.__class__
    s2 = t.stream(1000, seed=0)
    assert np.array_equal(s, s2)


# ---------------------------------------------------------------------------
# checkpoint (property-based roundtrip)
# ---------------------------------------------------------------------------

leaf = st.sampled_from([np.float32, np.int32]).flatmap(
    lambda d: st.integers(0, 3).map(
        lambda nd: np.arange(int(np.prod([2] * nd)), dtype=d).reshape([2] * nd)
    )
)
trees = st.recursive(
    leaf,
    lambda children: st.one_of(
        st.dictionaries(st.sampled_from(list("abcde")), children, min_size=1, max_size=3),
        st.lists(children, min_size=1, max_size=3),
        st.tuples(children, children),
    ),
    max_leaves=8,
)


@given(tree=trees)
@settings(max_examples=40, deadline=None)
def test_checkpoint_roundtrip(tmp_path_factory, tree):
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.npz")
        ckpt.save(path, tree)
        back = ckpt.load(path)
    assert jax.tree.structure(tree) == jax.tree.structure(back)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# privacy
# ---------------------------------------------------------------------------

def test_dcor_bounds(key):
    x = jax.random.normal(key, (128, 32))
    assert 0.0 <= float(dcor(x, x)) <= 1.0 + 1e-5
    assert float(dcor(x, x)) > 0.99      # self-correlation ~1
    z = jax.random.normal(jax.random.PRNGKey(9), (128, 8))
    assert float(dcor(x, z)) < float(dcor(x, x))


def test_dcor_exact_zero_for_degenerate_inputs(key):
    """Regression: the epsilon used to sit INSIDE the sqrt, flooring every
    result at ~1e-6 (biasing the Table-5 alpha sweep near dcor = 0). Now
    zero-variance inputs return exactly 0.0, gradients stay finite."""
    z = jax.random.normal(key, (32, 8))
    const = jnp.ones((32, 8))
    assert float(dcor(const, z)) == 0.0
    assert float(dcor(z, const)) == 0.0
    g = jax.grad(lambda a: dcor(a, z))(const)
    assert np.isfinite(np.asarray(g)).all()
    g2 = jax.grad(lambda a: dcor(a, z))(z * 0.1 + 1.0)  # nondegenerate path
    assert np.isfinite(np.asarray(g2)).all()


@given(n=st.integers(2, 16), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_patch_shuffle_preserves_multiset(n, seed):
    z = jnp.arange(4 * 32.0).reshape(4, 32)
    out = patch_shuffle(jax.random.PRNGKey(seed), z, n_patches=n)
    np.testing.assert_allclose(np.sort(np.asarray(out), axis=1),
                               np.sort(np.asarray(z), axis=1))
