"""Paper-native ResNet path: module splits, aux heads, Table-10 channels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet_cifar import RESNET56, RESNET110, get_resnet
from repro.core import splitting
from repro.models import resnet as R


def _split(p, cfg, tier_module):
    nb = R.n_blocks_in_modules(cfg, tier_module)
    return splitting.split_params(p, nb, splitting.RESNET)


@pytest.mark.parametrize("cfg", [RESNET56.reduced(), RESNET56, RESNET110])
def test_forward_and_splits(cfg, key):
    p = R.init(key, cfg)
    x = jax.random.normal(key, (2, cfg.image_size, cfg.image_size, 3))
    want = R.forward(p, cfg, x)
    assert want.shape == (2, cfg.n_classes)
    for tier in range(1, cfg.n_modules):
        c, s = _split(p, cfg, tier)
        z = R.client_forward(c, cfg, x)
        got = R.server_forward(s, cfg, z, tier)
        np.testing.assert_allclose(want, got, atol=1e-4)
        aux = R.aux_apply(R.aux_init(key, cfg, tier), z)
        assert aux.shape == (2, cfg.n_classes)


def test_block_plan_56_110_depths():
    # ResNet-6n+2 bottleneck: 56 -> n=6 per stage; 110 -> n=12
    assert len(R._block_plan(RESNET56)) == 18
    assert len(R._block_plan(RESNET110)) == 36


def test_table10_aux_channels():
    """Aux fc input widths per tier must follow Table 10 (16,64,64,128,128,256,256
    for the paper's width-16 stacks)."""
    w = RESNET56.width
    chans = [R.aux_channels(RESNET56, t) for t in range(1, 8)]
    assert chans == [w, 4 * w, 4 * w, 8 * w, 8 * w, 16 * w, 16 * w]


def test_merge_roundtrip(key):
    cfg = RESNET56.reduced()
    p = R.init(key, cfg)
    c, s = _split(p, cfg, 2)
    m = splitting.merge_params(c, s, splitting.RESNET)
    assert jax.tree.all(jax.tree.map(jnp.array_equal, p, m))


def test_module_boundaries_cover_all_blocks():
    for cfg in (RESNET56, RESNET110):
        assert R.n_blocks_in_modules(cfg, 7) == cfg.n_blocks
        assert R.n_blocks_in_modules(cfg, 1) == 0  # md1 is the stem only
