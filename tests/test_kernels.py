"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels.dcor import pairwise_dist


def mk(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("S", [128, 256, 512])
@pytest.mark.parametrize("hd", [64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
def test_flash_attention_sweep(S, hd, dtype, causal, window, key):
    BH = 3
    ks = jax.random.split(key, 3)
    q, k, v = (mk(ks[i], (BH, S, hd), dtype) for i in range(3))
    out = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block_q, block_k, key):
    BH, S, hd = 2, 256, 64
    ks = jax.random.split(key, 3)
    q, k, v = (mk(ks[i], (BH, S, hd), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, causal=True, block_q=block_q, block_k=block_k)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,chunk", [(128, 32), (256, 64), (256, 256), (96, 32)])
@pytest.mark.parametrize("dh", [32, 64])
def test_mlstm_chunk_sweep(S, chunk, dh, key):
    BH = 2
    ks = jax.random.split(key, 5)
    q = 0.5 * jax.random.normal(ks[0], (BH, S, dh))
    k = 0.5 * jax.random.normal(ks[1], (BH, S, dh))
    v = 0.5 * jax.random.normal(ks[2], (BH, S, dh))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (BH, S)) + 2.0)
    ig = jax.nn.sigmoid(jax.random.normal(ks[4], (BH, S)))
    out = mlstm_chunk(q, k, v, lf, ig, chunk=chunk, interpret=True)
    want = ref.mlstm_ref(q, k, v, lf, ig)
    np.testing.assert_allclose(out, want, atol=5e-4, rtol=5e-4)


def test_mlstm_kernel_matches_model_chunk_scan(key):
    """The pure-jnp chunkwise form in models/ssm.py is itself validated
    against the naive recurrence (and thus against the kernel)."""
    from repro.models.ssm import _mlstm_chunk_scan

    BH, S, dh = 2, 128, 32
    ks = jax.random.split(key, 5)
    B, H = 1, 2
    q = 0.5 * jax.random.normal(ks[0], (B, H, S, dh))
    k = 0.5 * jax.random.normal(ks[1], (B, H, S, dh))
    v = 0.5 * jax.random.normal(ks[2], (B, H, S, dh))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S)) + 2.0)
    ig = jax.nn.sigmoid(jax.random.normal(ks[4], (B, H, S)))
    C0 = jnp.zeros((B, H, dh, dh))
    n0 = jnp.zeros((B, H, dh))
    h, _, _ = _mlstm_chunk_scan(q, k, v, lf, ig, C0, n0)
    want = ref.mlstm_ref(
        q.reshape(B * H, S, dh), k.reshape(B * H, S, dh), v.reshape(B * H, S, dh),
        lf.reshape(B * H, S), ig.reshape(B * H, S),
    ).reshape(B, H, S, dh)
    np.testing.assert_allclose(h, want, atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("B,F", [(64, 128), (128, 300), (96, 64)])
def test_pairwise_dist(B, F, key):
    x = jax.random.normal(key, (B, F))
    out = pairwise_dist(x, interpret=True)
    want = ref.pairwise_dist_ref(x)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_dcor_kernel_matches_jnp(key):
    from repro.privacy import dcor

    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (64, 48))
    z = x @ jax.random.normal(ks[1], (48, 8))
    np.testing.assert_allclose(ops.dcor_op(x, z), dcor(x, z), atol=1e-4)


def test_flash_attention_inference_batch(key):
    """Serving-style call: many (batch*head) programs, window masking."""
    BH, S, hd = 8, 256, 64
    ks = jax.random.split(key, 3)
    q, k, v = (mk(ks[i], (BH, S, hd), jnp.bfloat16) for i in range(3))
    out = flash_attention(q, k, v, causal=True, window=96, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=96)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=3e-2, rtol=3e-2
    )


@pytest.mark.parametrize("shape", [(64,), (77, 130), (4, 8, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_quantize_kernel_matches_ref(shape, dtype, key):
    from repro.kernels.quantize import int8_roundtrip

    x = mk(key, shape, dtype)
    out = int8_roundtrip(x, interpret=True)
    want = ref.int8_roundtrip_ref(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("block", [64, 1000, 4096])
def test_int8_quantize_kernel_block_shapes(block, key):
    from repro.kernels.quantize import int8_roundtrip

    x = mk(key, (501,), jnp.float32)  # deliberately not a block multiple
    np.testing.assert_array_equal(
        np.asarray(int8_roundtrip(x, block=block, interpret=True)),
        np.asarray(ref.int8_roundtrip_ref(x)))


def test_int8_op_matches_codec_jnp_body(key):
    from repro.core.codec import Int8Codec

    x = mk(key, (96, 64), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.int8_roundtrip_op(x)),
        np.asarray(Int8Codec().rt(x)))


def test_int8_all_zero_input_is_stable():
    from repro.kernels.quantize import int8_roundtrip

    x = jnp.zeros((130,))
    out = int8_roundtrip(x, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("T,V,bv", [(128, 1000, 256), (256, 2048, 2048), (64, 777, 128)])
def test_fused_xent_sweep(T, V, bv, key):
    from repro.kernels.fused_xent import fused_xent

    ks = jax.random.split(key, 2)
    logits = 4.0 * jax.random.normal(ks[0], (T, V))
    labels = jax.random.randint(ks[1], (T,), 0, V)
    out = fused_xent(logits, labels, block_vocab=bv, interpret=True)
    want = ref.fused_xent_ref(logits, labels)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)


def test_fused_xent_op_matches_token_xent(key):
    from repro.core.local_loss import token_xent

    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (4, 32, 512))
    labels = jax.random.randint(ks[1], (4, 32), 0, 512)
    np.testing.assert_allclose(
        ops.fused_xent_op(logits, labels), token_xent(logits, labels), atol=1e-5
    )
