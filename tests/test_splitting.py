"""Unified split plane (core/splitting.py): lossless round trips for both
archs at every boundary, and the delegation from tiering / the adapters."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.resnet_cifar import RESNET56
from repro.core import splitting, tiering
from repro.models import model as M
from repro.models import resnet as R


def _trees_equal(a, b) -> bool:
    return jax.tree.all(jax.tree.map(jnp.array_equal, a, b))


def test_resnet_roundtrip_every_boundary(key):
    cfg = RESNET56.reduced()
    params = R.init(key, cfg)
    n = len(params["blocks"])
    for boundary in range(n + 1):
        near, far = splitting.split_params(params, boundary, splitting.RESNET)
        assert "stem" in near and "fc" in far
        assert len(near["blocks"]) == boundary
        assert len(far["blocks"]) == n - boundary
        merged = splitting.merge_params(near, far, splitting.RESNET)
        assert _trees_equal(params, merged), boundary


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_transformer_roundtrip_every_boundary(arch, key):
    cfg = get_config(arch).reduced().replace(tie_embeddings=False, n_modules=3)
    params = M.init(key, cfg)
    for boundary in range(cfg.n_layers + 1):
        near, far = splitting.split_params(params, boundary,
                                           splitting.TRANSFORMER)
        merged = splitting.merge_params(near, far, splitting.TRANSFORMER)
        assert _trees_equal(params, merged), (arch, boundary)


def test_resnet_split_matches_module_boundary(key):
    """The adapter's split must land client blocks exactly at the paper's
    module boundary (pre-refactor models/resnet.py:split_params semantics)."""
    cfg = RESNET56.reduced()
    params = R.init(key, cfg)
    for tier_module in range(1, cfg.n_modules):
        nb = R.n_blocks_in_modules(cfg, tier_module)
        near, far = splitting.split_params(params, nb, splitting.RESNET)
        assert _trees_equal(near["stem"], params["stem"])
        assert _trees_equal(far["fc"], params["fc"])
        assert _trees_equal(near["blocks"], params["blocks"][:nb])
        assert _trees_equal(far["blocks"], params["blocks"][nb:])


def test_tiering_delegates_to_splitting(key):
    """tiering.split_params(cfg, tier) == splitting at split_layer(cfg, tier)."""
    cfg = get_config(ASSIGNED_ARCHS[0]).reduced().replace(
        tie_embeddings=False, n_modules=3)
    params = M.init(key, cfg)
    for tier in range(1, tiering.n_tiers(cfg) + 1):
        via_tiering = tiering.split_params(params, cfg, tier)
        via_splitting = splitting.split_params(
            params, tiering.split_layer(cfg, tier), splitting.TRANSFORMER)
        for a, b in zip(via_tiering, via_splitting):
            assert _trees_equal(a, b), tier


def test_resnet_has_no_local_split():
    """The duplicated resnet-local split/merge is gone; core/splitting.py is
    the single home (the tentpole's dedup)."""
    assert not hasattr(R, "split_params")
    assert not hasattr(R, "merge_params")
