"""Population-plane lockdown: the equivalence/regression harness for the
lazy client-state store, the chunked execution plane, and the incremental
scheduler.

Four families of guarantees:

* chunked == cohort BIT-FOR-BIT: a cohort run and a chunked run of the same
  spec produce identical params, aux heads, accuracies, assignments,
  scheduler observations, and uplink bytes — for DTFL and FedAvg, under the
  rounds and events engines, and with the stateful topk+EF codec.
* lazy-store properties: a never-sampled client allocates no state; a
  resampled client's state round-trips the checkpoint envelope
  bit-deterministically; compaction after churn never drops a live
  client's EF residual. (Hypothesis variants run where the library is
  installed — tests/hyputil.py — with deterministic fallbacks always on.)
* incremental scheduler == dense rebuild: the cached estimate-matrix rows
  equal an independent from-scratch Eq.-5 computation, assignments are
  exact, and ``_row_recomputes`` tracks observations, not registry size.
* O(population) hotspot regressions: int-pool sampling is stream-identical
  to the arange it replaced, and per-round sampling cost is O(sample).
"""
import json
import os

import jax
import numpy as np
import pytest
from hyputil import given, settings, st

from repro import checkpoint as ckpt
from repro import optim
from repro.api import ExperimentSpec, SpecError
from repro.configs.resnet_cifar import RESNET_MICRO
from repro.core.scheduler import DynamicTierScheduler, TierProfile
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import (ClientStore, DTFLTrainer, LazyHeteroEnv, ResNetAdapter,
                       SimClient)
from repro.fed import engine
from repro.fed.execplan import ExecPlan
from repro.fed.population import cid_rng

BASE = {
    "model": {"arch": "resnet-micro", "full_size": True, "cost_model": "self"},
    "data": {"clients": 5, "samples": 320, "batch_size": 8, "iid": True},
    "env": {"switch_every": 0},
    "rounds": 2,
}


def _run(overrides):
    spec = ExperimentSpec.from_dict({**BASE, **overrides})
    fed = spec.build()
    return fed, fed.run()


def _leaves_equal(a, b, what=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _assert_same_run(fa, la, fb, lb):
    """Bit-for-bit run equality: params, aux, logs, scheduler state, EF."""
    ta, tb = fa.trainer, fb.trainer
    _leaves_equal(ta.params, tb.params, "params")
    for tier in getattr(ta, "aux", {}):
        _leaves_equal(ta.aux[tier], tb.aux[tier], f"aux[{tier}]")
    assert [l.acc for l in la] == [l.acc for l in lb]
    assert [l.clock for l in la] == [l.clock for l in lb]
    assert [l.assignment for l in la] == [l.assignment for l in lb]
    assert [l.uplink_bytes for l in la] == [l.uplink_bytes for l in lb]
    if hasattr(ta, "sched") and hasattr(ta.sched, "clients"):
        ia, ib = (ta.sched.clients.touched_items(),
                  tb.sched.clients.touched_items())
        assert [k for k, _ in ia] == [k for k, _ in ib]
        for (_, ca), (_, cb) in zip(ia, ib):
            assert (ca.tier, ca.nu, ca.n_batches, ca.last_obs_tier) == (
                cb.tier, cb.nu, cb.n_batches, cb.last_obs_tier)
            assert set(ca.ema) == set(cb.ema)
            for m in ca.ema:
                assert ca.ema[m].value == cb.ema[m].value
    efa, efb = getattr(ta, "_ef", {}), getattr(tb, "_ef", {})
    assert set(efa) == set(efb)
    for cid in efa:
        assert efa[cid]["tier"] == efb[cid]["tier"]
        _leaves_equal(efa[cid]["c"], efb[cid]["c"], f"ef[{cid}].c")
        _leaves_equal(efa[cid]["a"], efb[cid]["a"], f"ef[{cid}].a")


# ---------------------------------------------------------------------------
# chunked == cohort bit-equality (the tentpole's execution contract)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cohort_dtfl():
    return _run({"exec": {"mode": "cohort"}})


# 1 (one client per program call), 3 (ragged: 5 clients pad to 6), and 5
# (chunk == whole cohort) cover the degenerate, padded, and identity chunkings
@pytest.mark.parametrize("chunk", [1, 3, 5])
def test_chunked_equals_cohort_dtfl(cohort_dtfl, chunk):
    fa, la = _run({"exec": {"mode": "chunked", "chunk_size": chunk}})
    _assert_same_run(fa, la, *cohort_dtfl)


@pytest.mark.parametrize("chunk", [1, 3])
def test_chunked_equals_cohort_fedavg(chunk):
    fa, la = _run({"trainer": {"method": "fedavg"},
                   "exec": {"mode": "chunked", "chunk_size": chunk}})
    fb, lb = _run({"trainer": {"method": "fedavg"},
                   "exec": {"mode": "cohort"}})
    _assert_same_run(fa, la, fb, lb)


def test_chunked_equals_cohort_events_engine():
    fa, la = _run({"engine": {"name": "events"},
                   "exec": {"mode": "chunked", "chunk_size": 2}})
    fb, lb = _run({"engine": {"name": "events"}, "exec": {"mode": "cohort"}})
    _assert_same_run(fa, la, fb, lb)


def test_chunked_equals_cohort_topk_ef_codec():
    """The stateful codec path: per-client error-feedback residuals must be
    gathered/scattered per chunk without perturbing the compressed stream."""
    fa, la = _run({"codec": {"name": "topk0.25"},
                   "exec": {"mode": "chunked", "chunk_size": 3}})
    fb, lb = _run({"codec": {"name": "topk0.25"}, "exec": {"mode": "cohort"}})
    assert fa.trainer._ef, "topk run recorded no EF residuals"
    _assert_same_run(fa, la, fb, lb)


def test_chunked_equals_cohort_population_rounds_vs_events():
    """Population mode composes with both sync engines: same registry, same
    sample_size, chunked — the events engine (no churn) must reproduce the
    scalar-clock loop bit-for-bit, and both stay O(sample)."""
    ov = {"data": {"population": 40, "samples": 24, "batch_size": 8,
                   "iid": True},
          "trainer": {"sample_size": 4},
          "exec": {"mode": "chunked", "chunk_size": 2}}
    fa, la = _run({**ov, "engine": {"name": "rounds"}})
    fb, lb = _run({**ov, "engine": {"name": "events"}})
    _assert_same_run(fa, la, fb, lb)
    assert fa.trainer.clients.n_touched <= 2 * 4 + 1


# ---------------------------------------------------------------------------
# lazy client-state store properties
# ---------------------------------------------------------------------------

@given(touch=st.lists(st.integers(0, 199), max_size=40),
       n=st.integers(200, 5000))
@settings(max_examples=30, deadline=None)
def test_store_materializes_exactly_touched(touch, n):
    built = []
    store = ClientStore(n, lambda cid: built.append(cid) or ("client", cid))
    for cid in touch:
        assert store[cid] == ("client", cid)
    assert store.touched() == sorted(set(touch))
    assert store.n_touched == len(set(touch)) == len(built)


def test_store_materializes_exactly_touched_deterministic():
    built = []
    store = ClientStore(10_000, lambda cid: built.append(cid) or ("c", cid))
    for cid in (3, 9999, 3, 0, 512):
        assert store[cid] == ("c", cid)
    assert store.touched() == [0, 3, 512, 9999]
    assert store.n_touched == len(built) == 4  # repeat access hits the cache
    with pytest.raises(IndexError):
        store[10_000]
    store.compact([3, 512])
    assert store.touched() == [3, 512]
    # a compacted client rebuilds identically from the factory
    assert store[9999] == ("c", 9999)


def test_never_sampled_client_allocates_no_state():
    """End-to-end: after a population-mode run, materialized client/
    scheduler/env state covers only the sampled participants (plus client 0,
    which the trainer constructor reads for its batch size)."""
    fed, logs = _run({"data": {"population": 300, "samples": 24,
                               "batch_size": 8, "iid": True},
                      "trainer": {"sample_size": 5},
                      "exec": {"mode": "chunked", "chunk_size": 5}})
    tr = fed.trainer
    sampled = set().union(*(l.assignment.keys() for l in logs))
    assert set(tr.clients.touched()) <= sampled | {0}
    assert set(tr.sched.clients.touched()) <= sampled | {0}
    assert tr.clients.n_touched < 300 / 4  # nowhere near the registry


def _pop_setup(n=40, per=24, bs=8):
    task = ClassImageTask(n_classes=10, image_size=RESNET_MICRO.image_size)

    def factory(cid):
        labels = cid_rng(0, 21, cid).integers(0, 10, per)
        return SimClient(
            cid, ClientDataset(task, labels, np.arange(per), bs, seed=cid + 1),
            None)

    return (ResNetAdapter(RESNET_MICRO, cost_cfg=None),
            ClientStore(n, factory), make_eval_batch(task, 32))


def _pop_trainer(adapter, clients):
    # switch_every=2 exercises the lazy env's switch log across the
    # checkpoint boundary
    return DTFLTrainer(adapter, clients,
                       LazyHeteroEnv(len(clients), switch_every=2, seed=0),
                       optim.adam(1e-3), seed=0, exec_plan=ExecPlan.chunked(2))


@pytest.mark.parametrize("eng", ["rounds", "events"])
def test_resampled_state_roundtrips_checkpoint(tmp_path, eng):
    """Run 4 population-mode rounds straight == run 2, checkpoint, resume in
    a fresh trainer, run 2 more — params, scheduler EMA history, and lazy-env
    profiles all bit-for-bit (clients resampled after the resume hit their
    pre-checkpoint state)."""
    p = os.path.join(str(tmp_path), "state.npz")
    adapter, store, ev = _pop_setup()
    straight = _pop_trainer(adapter, store)
    straight.run(4, ev, sample_size=3, engine=eng)

    first = _pop_trainer(*_pop_setup()[:2])
    first.run(2, ev, sample_size=3, engine=eng,
              checkpoint_path=p, checkpoint_every=2)
    env = ckpt.load(p)
    # the envelope is SPARSE: it carries the touched clients, not the registry
    n_saved = len(np.asarray(env["trainer"]["sched"]["cids"]).reshape(-1))
    assert n_saved == first.sched.clients.n_touched < 40
    assert "lazy" in env["trainer"]["env"]

    resumed = _pop_trainer(*_pop_setup()[:2])
    resumed.run(4, ev, sample_size=3, engine=eng, resume=env)

    _leaves_equal(straight.params, resumed.params, "params")
    ia, ib = (straight.sched.clients.touched_items(),
              resumed.sched.clients.touched_items())
    assert [k for k, _ in ia] == [k for k, _ in ib]
    for (_, ca), (_, cb) in zip(ia, ib):
        assert ca.tier == cb.tier and ca.last_obs_tier == cb.last_obs_tier
        for m in ca.ema:
            assert ca.ema[m].value == cb.ema[m].value
    for cid in straight.sched.clients.touched():
        assert (straight.env.profile_idx(cid) == resumed.env.profile_idx(cid))


def test_lazy_env_rejects_dense_envelope():
    env = LazyHeteroEnv(10, seed=0)
    with pytest.raises(ValueError, match="dense"):
        env.load_state({"assignment": np.zeros(10, np.int64)})


def test_lazy_env_resolution_is_order_independent():
    """A profile resolved eagerly (cached before switches) equals one
    resolved lazily after the full switch log — cache invalidation cannot
    change the draw."""
    a = LazyHeteroEnv(1000, switch_every=2, switch_frac=0.5, seed=7)
    b = LazyHeteroEnv(1000, switch_every=2, switch_frac=0.5, seed=7)
    cids = [0, 1, 17, 999]
    for cid in cids:
        a.profile_idx(cid)          # eager: populate the cache early
    for r in range(1, 9):
        a.maybe_switch(r)
        a.maybe_switch(r)           # idempotent per round
        b.maybe_switch(r)
        for cid in cids:
            a.profile_idx(cid)
    assert [a.profile_idx(c) for c in cids] == [b.profile_idx(c) for c in cids]
    # an override pins the profile from its log position onward
    b.set_profile(17, 2)
    c = LazyHeteroEnv(1000, switch_every=2, switch_frac=0.5, seed=7)
    c.load_state(b.save_state())
    assert [c.profile_idx(k) for k in cids] == [b.profile_idx(k) for k in cids]


@given(keep_frac=st.floats(0.1, 0.9), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_compaction_never_drops_live_state(keep_frac, seed):
    """Property variant (store + scheduler level): compacting to any keep
    set preserves every kept client's state exactly and drops the rest."""
    rng = np.random.default_rng(seed)
    store = ClientStore(5000, lambda cid: ("c", cid))
    s = DynamicTierScheduler(_make_profile(seed % 5), n_clients=5000)
    touched = sorted(set(rng.integers(0, 5000, 60).tolist()))
    for k in touched:
        store[k]
        s.observe(k, tier=0, total_client_time=1.0 + k % 5, nu=1e6,
                  n_batches=2)
    keep = sorted(k for k in touched if rng.random() < keep_frac)
    vals = {k: s.clients[k].ema[0].value for k in keep}
    store.compact(keep)
    s.compact(keep)
    assert store.touched() == keep == s.clients.touched()
    for k in keep:
        assert s.clients[k].ema[0].value == vals[k]


def test_compaction_never_drops_live_ef_deterministic():
    """After churn compaction, every surviving client's EF residual (and
    scheduler history) is untouched; departed clients' state is gone."""
    fed, _ = _run({"data": {"population": 40, "samples": 24, "batch_size": 8,
                            "iid": True},
                   "trainer": {"sample_size": 6},
                   "codec": {"name": "topk0.25"},
                   "exec": {"mode": "chunked", "chunk_size": 3}})
    tr = fed.trainer
    assert tr._ef, "no EF residuals recorded"
    with_ef = sorted(tr._ef)
    live, gone = with_ef[::2], with_ef[1::2]
    snapshot = {cid: jax.tree.map(np.copy, tr._ef[cid]["c"]) for cid in live}
    tr.compact(live)
    assert sorted(tr._ef) == sorted(live)
    for cid in live:
        _leaves_equal(tr._ef[cid]["c"], snapshot[cid], f"ef[{cid}]")
        assert tr.sched.clients.is_touched(cid)
    for cid in gone:
        assert cid not in tr._ef
        assert not tr.sched.clients.is_touched(cid)
        assert cid not in tr.clients.touched()


# ---------------------------------------------------------------------------
# incremental scheduler == dense rebuild
# ---------------------------------------------------------------------------

def _make_profile(M=7, seed=0):
    rng = np.random.default_rng(seed)
    return TierProfile(
        t_client_ref=np.sort(rng.uniform(1.0, 10.0, M)),
        t_server_ref=np.sort(rng.uniform(0.5, 5.0, M))[::-1].copy(),
        d_size=np.sort(rng.uniform(1e5, 1e7, M))[::-1].copy(),
    )


def _dense_reference(s, ks):
    """Independent from-scratch Eq.-5 (K, M) rebuild — the dense computation
    the incremental cache replaced, re-derived here so the test does not
    share code with the implementation."""
    prof = s.profile
    out = np.empty((len(ks), prof.n_tiers))
    for i, k in enumerate(ks):
        if s.clients.is_touched(k):
            st_ = s.clients[k]
            nu, nb, m0 = float(st_.nu), float(st_.n_batches), st_.last_obs_tier
            ema = st_.ema[m0].value if m0 is not None else None
        else:
            nu, nb, m0, ema = 1e6, 1.0, None, None
        t_com = (prof.z_bytes * nb + prof.param_bytes) / nu
        t_srv = prof.t_server_ref * nb
        t_cli = (prof.t_client_ref * nb if m0 is None
                 else prof.t_client_ref / prof.t_client_ref[m0] * ema)
        out[i] = np.maximum(t_cli + t_com, t_srv + t_com)
    return out


def _reference_assign(s, dense, ks):
    sel = np.array(s.allowed)
    est = dense[:, sel]
    t_max = est.min(axis=1).max()
    feasible = est <= t_max + 1e-12
    assign = {}
    for i, k in enumerate(ks):
        ok = np.flatnonzero(feasible[i])
        assign[k] = int(sel[ok.max()]) if len(ok) else int(sel[est[i].argmin()])
    return assign


def _synthetic_rounds(s, n_rounds, sample, lo_cid=0, hi_cid=1000, seed=4):
    rng = np.random.default_rng(seed)
    for r in range(n_rounds):
        ks = sorted(rng.choice(np.arange(lo_cid, hi_cid), sample,
                               replace=False).tolist())
        s.schedule(ks)
        for k in ks:
            s.observe(k, tier=s.clients[k].tier,
                      total_client_time=1.0 + (k % 7) + 0.1 * r,
                      nu=1e6 * (1 + k % 3), n_batches=2 + k % 4)


def test_incremental_matrix_equals_dense_rebuild():
    s = DynamicTierScheduler(_make_profile(), n_clients=10_000)
    _synthetic_rounds(s, n_rounds=6, sample=32)
    rng = np.random.default_rng(9)
    # mix of observed, schedule-touched, and never-seen clients
    ks = sorted(set(s.clients.touched()[:40])
                | set(rng.integers(0, 10_000, 20).tolist()))
    dense = _dense_reference(s, ks)
    np.testing.assert_allclose(s.estimate_matrix(ks), dense, rtol=1e-12)
    assert s.schedule(ks) == _reference_assign(s, dense, ks)


def test_row_recomputes_track_observations_not_registry():
    """The micro-benchmark claim: the identical observation/schedule sequence
    costs the identical number of row rebuilds on a 10^3- and a 10^6-client
    registry — update cost is O(observed), never O(population)."""
    counts = {}
    for n in (1_000, 1_000_000):
        s = DynamicTierScheduler(_make_profile(), n_clients=n)
        _synthetic_rounds(s, n_rounds=5, sample=16)
        s.estimate_matrix(list(range(0, 1000, 100)))
        counts[n] = s._row_recomputes
    assert counts[1_000] == counts[1_000_000]
    # ceiling: one rebuild per (participant x round) + the final estimate
    # call + the shared default row — NOT a function of n
    assert counts[1_000_000] <= 5 * 16 * 2 + 10 + 1


def test_schedule_only_touches_participants():
    s = DynamicTierScheduler(_make_profile(), n_clients=500_000)
    s.schedule([3, 77, 400_000])
    assert s.clients.touched() == [3, 77, 400_000]
    assert len(s._rows) <= 3


# ---------------------------------------------------------------------------
# O(population) hotspot regressions (fed/engine.py sampling)
# ---------------------------------------------------------------------------

def test_int_pool_sampling_stream_identical_to_arange():
    """run_events' churn-free pool is now the population SIZE; the rng must
    consume the identical stream as the arange it replaced (golden runs)."""
    a, b = np.random.default_rng(0), np.random.default_rng(0)
    for k in (1, 5, 17, 256):
        np.testing.assert_array_equal(
            a.choice(10_000, k, replace=False),
            b.choice(np.arange(10_000), k, replace=False))


def test_round_sample_size():
    f = engine._round_sample_size
    assert f(100, 0.25, None) == 25          # legacy fractional sizing
    assert f(3, 0.1, None) == 1              # floor of one participant
    assert f(1_000_000, 1.0, 512) == 512     # absolute population sampling
    assert f(10, 1.0, 512) == 10             # capped at the registry
    with pytest.raises(ValueError, match="sample_size"):
        f(100, 1.0, 0)


# ---------------------------------------------------------------------------
# spec plumbing: validation + program identity
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(SpecError):
        ExperimentSpec.from_dict({**BASE, "exec": {"mode": "cohort",
                                                   "chunk_size": 4}})
    with pytest.raises(SpecError):
        ExperimentSpec.from_dict({**BASE, "exec": {"mode": "chunked",
                                                   "chunk_size": 0}})
    with pytest.raises(SpecError):
        ExperimentSpec.from_dict({
            **BASE, "data": {"population": 100, "samples": 24},
            "engine": {"name": "async"}})
    with pytest.raises(SpecError):
        ExperimentSpec.from_dict({**BASE, "trainer": {"sample_size": 4},
                                  "engine": {"name": "async"}})
    spec = ExperimentSpec.from_dict({**BASE, "exec": {"mode": "chunked"}})
    assert spec.exec.chunk_size is None      # plan default (16) applies late
    assert ExecPlan.chunked().chunk_size == 16
    with pytest.raises(ValueError, match="chunk_size"):
        ExecPlan(mode="cohort", chunk_size=4)


def test_chunk_size_enters_program_key():
    k1 = ExperimentSpec.from_dict(
        {**BASE, "exec": {"mode": "chunked", "chunk_size": 2}}).program_key()
    k2 = ExperimentSpec.from_dict(
        {**BASE, "exec": {"mode": "chunked", "chunk_size": 4}}).program_key()
    k3 = ExperimentSpec.from_dict({**BASE, "exec": {"mode": "cohort"}}
                                  ).program_key()
    assert len({k1, k2, k3}) == 3
    # population/sample_size are data-plane knobs: same compiled programs
    ka = ExperimentSpec.from_dict({
        **BASE, "data": {"population": 100, "samples": 24},
        "trainer": {"sample_size": 4}}).program_key()
    kb = ExperimentSpec.from_dict({
        **BASE, "data": {"population": 5000, "samples": 24},
        "trainer": {"sample_size": 8}}).program_key()
    assert ka == kb


def test_async_rejects_sample_size_at_run():
    adapter, store, ev = _pop_setup(n=8)
    tr = DTFLTrainer(adapter, store, LazyHeteroEnv(8, switch_every=0, seed=0),
                     optim.adam(1e-3), seed=0)
    with pytest.raises(ValueError, match="async"):
        tr.run(2, ev, engine="async", sample_size=4)


# ---------------------------------------------------------------------------
# bench regression gate (benchmarks/run.py --check)
# ---------------------------------------------------------------------------

def test_bench_check_gate(tmp_path, monkeypatch, capsys):
    bench_run = pytest.importorskip("benchmarks.run")
    fresh = {"10/loop": 1.0, "10/cohort": 0.4, "pop100000/s512/c64": 3.0}
    monkeypatch.setattr(bench_run, "_fresh_walls", lambda: dict(fresh))

    base = os.path.join(str(tmp_path), "BENCH_table4.json")
    bench_run._write_baseline(base)
    out = os.path.join(str(tmp_path), "fresh.json")
    assert bench_run._check_baseline(base, out=out) == 0
    assert os.path.exists(out)

    # >1.5x on any row fails; a baseline row missing from the fresh run
    # (device-dependent sharded_dN) is skipped, not failed
    monkeypatch.setattr(bench_run, "_fresh_walls",
                        lambda: {**fresh, "10/loop": 1.6})
    assert bench_run._check_baseline(base) == 1
    monkeypatch.setattr(
        bench_run, "_fresh_walls",
        lambda: {k: v for k, v in fresh.items() if k != "10/cohort"})
    assert bench_run._check_baseline(base) == 0


def test_bench_check_gate_table3(tmp_path, monkeypatch, capsys):
    """--check dispatches on meta.suite: the table3 baseline gates the
    simulated clocks AND the pairing-beats-dtfl invariant."""
    bench_run = pytest.importorskip("benchmarks.run")
    fresh = {"iid/dtfl": 30.0, "iid/dtfl_pairing": 27.0}
    monkeypatch.setattr(bench_run, "_fresh_table3", lambda meta: dict(fresh))

    base = os.path.join(str(tmp_path), "BENCH_table3.json")
    bench_run._write_baseline(base)
    with open(base) as f:
        meta = json.load(f)["meta"]
    assert meta["suite"] == "table3_baselines"

    out = os.path.join(str(tmp_path), "fresh.json")
    assert bench_run._check_baseline(base, out=out) == 0
    assert json.load(open(out))["meta"]["suite"] == "table3_baselines"

    # a >1.5x clock regression fails
    monkeypatch.setattr(bench_run, "_fresh_table3",
                        lambda meta: {**fresh, "iid/dtfl_pairing": 50.0})
    assert bench_run._check_baseline(base) >= 1
    # pairing merely *not beating* dtfl fails too, even inside tolerance
    monkeypatch.setattr(bench_run, "_fresh_table3",
                        lambda meta: {**fresh, "iid/dtfl_pairing": 31.0})
    assert bench_run._check_baseline(base) == 1
