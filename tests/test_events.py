"""Event engine: queue determinism, sync-mode equivalence vs the legacy
round loop, churn behaviour, and async-tier sanity.

The sync-mode contract (ISSUE 2 acceptance): a 20-client DTFL run through
``run(engine="events")`` must produce identical scheduler tier assignments
and a numerically close (atol 1e-5) clock/accuracy trajectory to the legacy
scalar-clock loop, because without churn the event schedule degenerates to
exactly the same numbers.
"""
import jax
import numpy as np
import pytest

from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.core.events import EventQueue
from repro.data.partition import iid_partition
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import (ChurnModel, DTFLTrainer, FedATTrainer, FedAvgTrainer,
                       HeteroEnv, ResNetAdapter, SimClient)


# ---------------------------------------------------------------------------
# core/events.py: the queue itself
# ---------------------------------------------------------------------------

def test_queue_orders_by_time_then_insertion():
    q = EventQueue()
    q.push(3.0, "c")
    q.push(1.0, "a1")
    q.push(1.0, "a2")  # same time: must pop after a1 (insertion order)
    q.push(2.0, "b")
    kinds = []
    while not q.empty():
        kinds.append(q.pop().kind)
    assert kinds == ["a1", "a2", "b", "c"]
    assert q.now == 3.0


def test_queue_cancel_and_past_guard():
    q = EventQueue()
    ev = q.push(1.0, "x")
    q.push(2.0, "y")
    ev.cancel()
    assert len(q) == 1
    assert q.pop().kind == "y"
    with pytest.raises(ValueError):
        q.push(1.0, "past")  # now == 2.0


def test_queue_drain_until():
    q = EventQueue()
    for t in (1.0, 2.0, 5.0):
        q.push(t, f"t{t}")
    due = [ev.kind for ev in q.drain_until(3.0)]
    assert due == ["t1.0", "t2.0"]
    assert q.now == 3.0  # clock advances even past the last due event
    assert len(q) == 1


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def build(n_clients, samples=640, batch=16, seed=0):
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(seed).integers(0, 10, samples)
    parts = iid_partition(labels, n_clients, seed)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], batch), None)
               for i in range(n_clients)]
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)
    return adapter, clients, make_eval_batch(task, 128)


def mk_dtfl(adapter, clients, **kw):
    return DTFLTrainer(adapter, clients, HeteroEnv(len(clients), seed=0),
                       optim.adam(1e-3), seed=0, **kw)


def assert_trees_close(a, b, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


# ---------------------------------------------------------------------------
# sync mode == legacy round loop (the ISSUE's acceptance criterion)
# ---------------------------------------------------------------------------

def test_sync_events_match_legacy_rounds_20_clients():
    adapter, clients, ev = build(20)
    legacy = mk_dtfl(adapter, clients)
    events = mk_dtfl(adapter, clients)
    l1 = legacy.run(3, ev)
    l2 = events.run(3, ev, engine="events")
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        assert a.assignment == b.assignment        # identical tier assignments
        assert a.clock == pytest.approx(b.clock, abs=1e-9)
        assert a.acc == pytest.approx(b.acc, abs=1e-5)
        assert a.straggler == pytest.approx(b.straggler, abs=1e-9)
    assert_trees_close(legacy.params, events.params)
    # scheduler observations identical: same EMA state per (client, tier)
    for c1, c2 in zip(legacy.sched.clients, events.sched.clients):
        assert c1.tier == c2.tier and c1.last_obs_tier == c2.last_obs_tier
        assert set(c1.ema) == set(c2.ema)
        for m in c1.ema:
            assert c1.ema[m].value == pytest.approx(c2.ema[m].value, rel=1e-12)


def test_sync_events_match_legacy_baseline():
    adapter, clients, ev = build(4, samples=200)
    mk = lambda: FedAvgTrainer(adapter, clients, HeteroEnv(4, seed=0),
                               optim.adam(1e-3), seed=0)
    l1 = mk().run(2, ev)
    l2 = mk().run(2, ev, engine="events")
    for a, b in zip(l1, l2):
        assert a.clock == pytest.approx(b.clock)
        assert a.acc == pytest.approx(b.acc, abs=1e-5)


# ---------------------------------------------------------------------------
# determinism under seed
# ---------------------------------------------------------------------------

def test_event_runs_deterministic_under_seed():
    """Same seeds -> identical event order, clocks, accs — twice, with churn."""
    def once():
        adapter, clients, ev = build(6, samples=240)
        churn = ChurnModel(6, drop_prob=0.3, switch_prob=0.2, seed=7)
        tr = mk_dtfl(adapter, clients)
        return tr.run(4, ev, engine="events", churn=churn)

    a, b = once(), once()
    assert [(l.clock, l.acc, tuple(sorted(l.assignment.items()))) for l in a] == \
           [(l.clock, l.acc, tuple(sorted(l.assignment.items()))) for l in b]


# ---------------------------------------------------------------------------
# churn
# ---------------------------------------------------------------------------

def test_dropout_mid_round_keeps_estimates_finite():
    """A dropped client leaves no observation; the scheduler's estimate
    matrix must stay finite and the dropped client must sit out rejoin_after
    rounds before it can be sampled again."""
    adapter, clients, ev = build(6, samples=240)
    churn = ChurnModel(6, drop_prob=0.5, rejoin_after=2, seed=3)
    tr = mk_dtfl(adapter, clients)
    logs = tr.run(4, ev, engine="events", churn=churn)
    est = tr.sched.estimate_matrix(list(range(6)))
    assert np.isfinite(est).all()
    assert all(np.isfinite(l.clock) and l.clock > 0 for l in logs)
    assert logs[-1].clock >= logs[0].clock


def test_churn_arrival_and_rejoin_bookkeeping():
    churn = ChurnModel(10, start_offline_frac=0.3, arrival_prob=1.0, seed=0)
    assert len(churn.active()) == 7
    active = churn.begin_round(0)            # arrival_prob=1: everyone joins
    assert len(active) == 10
    churn.mark_offline(4)
    assert 4 not in churn.active()
    churn.begin_round(1)                      # countdown 2 -> 1
    assert 4 not in churn.active()
    active = churn.begin_round(2)             # countdown expires
    assert 4 in active.tolist()


def test_mid_round_switch_reschedules_completion():
    """Profile switches mid-round change the round straggler vs the no-churn
    run, and the scheduler observes the event-derived (rescaled) time."""
    adapter, clients, ev = build(4, samples=160)
    base = mk_dtfl(adapter, clients).run(2, ev, engine="events")
    churn = ChurnModel(4, switch_prob=1.0, seed=5)  # every client switches
    tr = mk_dtfl(adapter, clients)
    logs = tr.run(2, ev, engine="events", churn=churn)
    assert logs[0].straggler != pytest.approx(base[0].straggler)
    est = tr.sched.estimate_matrix(list(range(4)))
    assert np.isfinite(est).all()


# ---------------------------------------------------------------------------
# async tiers
# ---------------------------------------------------------------------------

def test_async_dtfl_monotone_clock_and_progress():
    adapter, clients, ev = build(6, samples=240)
    tr = mk_dtfl(adapter, clients)
    logs = tr.run(3, ev, engine="async", n_groups=2)
    clocks = [l.clock for l in logs]
    assert clocks == sorted(clocks)
    assert len(logs) >= 3                      # profiling round + merges
    assert all(np.isfinite(l.acc) for l in logs)


def test_fedat_async_beats_own_sync_clock():
    """FedAT's per-tier pacing advances the virtual clock by group stragglers
    only — for the same per-group wave budget its final clock must be below
    the synchronous equivalent (every round = global straggler)."""
    adapter, clients, ev = build(6, samples=240)
    mk = lambda: FedATTrainer(adapter, clients, HeteroEnv(6, seed=0),
                              optim.adam(1e-3), seed=0, n_groups=2)
    async_logs = mk().run(2, ev)
    sync_logs = mk().run(1 + len(async_logs) - 1, ev, engine="rounds")
    # same number of aggregate updates; async merges on group stragglers
    assert async_logs[-1].clock < sync_logs[-1].clock
