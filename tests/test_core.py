"""Core DTFL semantics: local-loss isolation, aggregation, time model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.configs.resnet_cifar import RESNET56, RESNET110
from repro.core import aggregation, local_loss, tiering, timemodel
from repro.models import model as M


@pytest.fixture
def cfg():
    return get_config("smollm-360m").reduced().replace(
        tie_embeddings=False, n_modules=3
    )


def test_gradient_isolation(cfg, key):
    """No gradient flows server->client: the client update must be identical
    whatever the server-side parameters are (the paper's parallel-update
    property that removes the SL synchronization stall)."""
    params = M.init(key, cfg)
    opt = optim.sgd(0.1)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32), "labels": jnp.ones((2, 8), jnp.int32)}
    step = jax.jit(local_loss.make_dtfl_train_step(cfg, opt))

    st1 = local_loss.init_tier_state(key, cfg, params, 1, opt)
    out1, _ = step(st1, batch)

    # scramble the server params; client/aux results must not change
    scrambled = jax.tree.map(lambda a: a * 3.0 + 1.0, st1.server_params)
    st2 = st1._replace(server_params=scrambled,
                       server_opt=opt.init(scrambled))
    out2, _ = step(st2, batch)
    assert jax.tree.all(jax.tree.map(jnp.array_equal, out1.client_params, out2.client_params))
    assert jax.tree.all(jax.tree.map(jnp.array_equal, out1.aux_params, out2.aux_params))


def test_both_losses_decrease(cfg, key):
    params = M.init(key, cfg)
    opt = optim.adam(1e-3)
    state = local_loss.init_tier_state(key, cfg, params, 1, opt)
    step = jax.jit(local_loss.make_dtfl_train_step(cfg, opt))
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
             "labels": jnp.full((4, 16), 3, jnp.int32)}
    state, m0 = step(state, batch)
    for _ in range(10):
        state, m = step(state, batch)
    assert float(m.client_loss) < float(m0.client_loss)
    assert float(m.server_loss) < float(m0.server_loss)


def test_weighted_average():
    t1 = {"a": jnp.ones((2, 2)), "b": jnp.zeros(3)}
    t2 = {"a": 3 * jnp.ones((2, 2)), "b": jnp.ones(3)}
    avg = aggregation.weighted_average([t1, t2], [1.0, 3.0])
    assert jnp.allclose(avg["a"], 2.5)
    assert jnp.allclose(avg["b"], 0.75)


def test_cross_tier_aggregation_equals_merged_average(cfg, key):
    params = M.init(key, cfg)
    k2 = jax.random.split(key)[0]
    params2 = M.init(k2, cfg)
    c1, s1 = tiering.split_params(params, cfg, 1)
    c2, s2 = tiering.split_params(params2, cfg, 2)
    got = aggregation.aggregate_dtfl_round(cfg, [(1, c1, s1), (2, c2, s2)], [1.0, 1.0])
    want = aggregation.weighted_average([params, params2], [1.0, 1.0])
    assert jax.tree.all(jax.tree.map(lambda a, b: jnp.allclose(a, b), got, want))


# ---------------------------------------------------------------------------
# time model
# ---------------------------------------------------------------------------

def test_eq5_composition():
    costs = timemodel.resnet_tier_costs(RESNET56, batch_size=100)
    prof = timemodel.ResourceProfile(1.0, 30.0)
    t = timemodel.simulate_client_times(costs, 2, prof, 10)
    assert t["total"] == pytest.approx(max(t["client"] + t["comm"], t["server"] + t["comm"]))


def test_tier_monotonicity_resnet():
    """Higher tier => more client compute, fewer bytes (paper Table 1 shape)."""
    costs = timemodel.resnet_tier_costs(RESNET110, batch_size=100)
    assert np.all(np.diff(costs.client_flops) > 0)
    assert np.all(np.diff(costs.server_flops) < 0)
    # z bytes peak at md2/md3 (channel expansion) then shrink with the spatial
    # downsampling — the same shape as the paper's Table-1 communication row
    assert np.all(np.diff(costs.z_bytes[1:]) <= 0)
    assert costs.z_bytes[-1] < costs.z_bytes[1]
    assert np.all(np.diff(costs.client_param_bytes) > 0)


def test_table2_normalized_ratio_profile_independent():
    """Normalized per-tier times have client-independent ratios (Table 2)."""
    costs = timemodel.resnet_tier_costs(RESNET56, batch_size=100)
    t_fast = costs.client_flops / timemodel.ResourceProfile(4.0, 100.0).flops
    t_slow = costs.client_flops / timemodel.ResourceProfile(0.2, 30.0).flops
    np.testing.assert_allclose(t_fast / t_fast[0], t_slow / t_slow[0], rtol=1e-12)


def test_transformer_costs_full_flops_sane():
    cfg = get_config("yi-6b")
    costs = timemodel.transformer_tier_costs(cfg, batch_size=8, seq_len=256)
    # full model flops > any split side
    assert costs.full_flops > costs.client_flops.max() * 0.5
    assert costs.full_param_bytes == pytest.approx(
        M.count_params_analytic(cfg.replace(tie_embeddings=False)) * 4, rel=0.01
    )


def test_offloading_helps_slow_clients():
    """A weak client's total time should be better at SOME low tier than at
    the top tier — the paper's Table-1 phenomenon that motivates tiering."""
    costs = timemodel.resnet_tier_costs(RESNET110, batch_size=100)
    weak = timemodel.ResourceProfile(0.2, 30.0)
    times = [timemodel.simulate_client_times(costs, m, weak, 10)["total"]
             for m in range(costs.n_tiers)]
    assert np.argmin(times) < costs.n_tiers - 1
