"""Beyond-paper framework features: padded vocab, M-tier deployments,
sharding presets, serve variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES
from repro.core.local_loss import token_xent
from repro.core.scheduler import DynamicTierScheduler, TierProfile
from repro.models import model as M


# ---------------------------------------------------------------------------
# Megatron-style vocab padding
# ---------------------------------------------------------------------------

def test_padded_vocab_masked_and_finite(key):
    cfg = get_config("granite-3-2b").reduced().replace(
        dtype="float32", tie_embeddings=False, vocab=499, pad_vocab_multiple=64
    )
    assert cfg.padded_vocab == 512
    params = M.init(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab),
        "labels": jax.random.randint(key, (2, 8), 0, cfg.vocab),
    }
    logits, _ = M.forward(params, cfg, batch)
    assert logits.shape[-1] == 512
    # padded rows can never win the argmax and never blow up the loss
    assert bool((jnp.argmax(logits, -1) < cfg.vocab).all())
    assert bool(jnp.isfinite(token_xent(logits, batch["labels"])))


def test_padded_vocab_decode(key):
    cfg = get_config("yi-6b").reduced().replace(
        dtype="float32", vocab=500, pad_vocab_multiple=128
    )
    params = M.init(key, cfg)
    cache = M.init_cache(cfg, 2, 8)
    logits, cache = M.decode_step(params, cfg, jnp.zeros((2,), jnp.int32), cache)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool((jnp.argmax(logits, -1) < cfg.vocab).all())


# ---------------------------------------------------------------------------
# M-tier deployments (paper Table 11 semantics)
# ---------------------------------------------------------------------------

def test_m_tier_subset_scheduling():
    prof = TierProfile(
        t_client_ref=np.arange(1.0, 8.0),
        t_server_ref=np.zeros(7),
        d_size=np.zeros(7),
    )
    s = DynamicTierScheduler(prof, n_clients=2, allowed=[5, 6])  # M=2 deployment
    assign = s.schedule()
    assert set(assign.values()) <= {5, 6}
    s.observe(0, tier=6, total_client_time=100.0, nu=1e9, n_batches=1)
    s.observe(1, tier=6, total_client_time=1.0, nu=1e9, n_batches=1)
    assign = s.schedule()
    assert set(assign.values()) <= {5, 6}  # never leaves the deployment's tiers


def test_more_tiers_never_hurt():
    """With the full tier set available, the schedule's straggler is <= the
    straggler under any restricted (smaller-M) deployment."""
    rng = np.random.default_rng(0)
    prof = TierProfile(
        t_client_ref=np.sort(rng.uniform(1, 10, 7)),
        t_server_ref=np.sort(rng.uniform(0.5, 5, 7))[::-1].copy(),
        d_size=np.sort(rng.uniform(1e5, 1e7, 7))[::-1].copy(),
    )
    speeds = [4.0, 1.0, 0.1]

    def run(allowed):
        s = DynamicTierScheduler(prof, n_clients=3, allowed=allowed)
        for _ in range(4):
            assign = s.schedule()
            for k, cpu in enumerate(speeds):
                tier = assign[k]
                t = prof.t_client_ref[tier] * 10 / cpu
                s.observe(k, tier=tier, total_client_time=t, nu=1e9, n_batches=10)
        assign = s.schedule()
        return s.round_time(assign)

    full = run(list(range(7)))
    for m in (1, 2, 4):
        assert full <= run(list(range(7))[-m:]) + 1e-9, m


# ---------------------------------------------------------------------------
# sharding presets produce valid specs (host-side; no 512-device mesh needed)
# ---------------------------------------------------------------------------

def test_preset_specs_shapes():
    from jax.sharding import PartitionSpec as P
    from repro.launch import specs as S
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("yi-6b")
    mesh = make_host_mesh()
    shape = INPUT_SHAPES["train_4k"]
    for preset in ("baseline", "seqpar", "megatron_sp"):
        acts = S.activation_pspecs(cfg, shape, mesh, preset)
        assert "act" in acts and "z" in acts
    shape = INPUT_SHAPES["decode_32k"]
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 4, 64))
    for preset in ("baseline", "serve_dp", "serve_seq"):
        cs = S.cache_pspecs(cache, shape, mesh, preset)
        assert jax.tree.structure(cs) == jax.tree.structure(
            jax.tree.map(lambda _: P(), cache)
        )


def test_serve_preset_strips_fsdp():
    from jax.sharding import PartitionSpec as P
    from repro.launch import specs as S

    cfg = get_config("yi-6b")
    shapes = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    base = S.tree_pspecs(shapes)
    serve = S.tree_pspecs(shapes, preset="serve_dp")
    def has_data(spec):
        return any(ax == "data" for ax in spec)
    assert any(has_data(s) for s in jax.tree.leaves(base, is_leaf=lambda x: isinstance(x, P)))
    assert not any(has_data(s) for s in jax.tree.leaves(serve, is_leaf=lambda x: isinstance(x, P)))


# ---------------------------------------------------------------------------
# gather-based MoE dispatch == one-hot dispatch (no-drop config)
# ---------------------------------------------------------------------------

def test_moe_gather_dispatch_matches_onehot(key):
    from repro.models import moe as moe_lib
    from repro.models.transformer import block_init

    cfg = get_config("deepseek-moe-16b").reduced().replace(
        dtype="float32", capacity_factor=4.0  # C = Tg -> no drops either path
    )
    bp = block_init(key, cfg, "moe")
    x = 0.5 * jax.random.normal(key, (2, 32, cfg.d_model))
    y1, a1 = moe_lib.moe_apply(x, bp["moe"], cfg)
    y2, a2 = moe_lib.moe_apply_gather(x, bp["moe"], cfg)
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(a1, a2, atol=1e-6)


# ---------------------------------------------------------------------------
# extra baselines: TiFL selection + straggler dropping
# ---------------------------------------------------------------------------

def test_extra_baselines_learn_and_are_fast_per_round():
    from repro import optim
    from repro.configs.resnet_cifar import RESNET56, RESNET110
    from repro.data.partition import iid_partition
    from repro.data.pipeline import ClientDataset, make_eval_batch
    from repro.data.synthetic import ClassImageTask
    from repro.fed import (DropStragglerTrainer, FedAvgTrainer, HeteroEnv,
                           ResNetAdapter, SimClient, TiFLTrainer)

    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, 1000)
    parts = iid_partition(labels, 5, 0)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(5)]
    ev = make_eval_batch(task, 256)
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET110)

    rounds = {}
    for cls in (TiFLTrainer, DropStragglerTrainer, FedAvgTrainer):
        tr = cls(adapter, clients, HeteroEnv(5, seed=0), __import__("repro.optim", fromlist=["adam"]).adam(1e-3), seed=0)
        logs = tr.run(4, ev)
        rounds[cls.__name__] = logs
        assert logs[-1].acc >= logs[0].acc - 0.05, cls.__name__
    # both straggler-avoidance baselines beat FedAvg's straggler time
    assert rounds["TiFLTrainer"][-1].straggler <= rounds["FedAvgTrainer"][-1].straggler
    assert rounds["DropStragglerTrainer"][-1].straggler <= rounds["FedAvgTrainer"][-1].straggler


# ---------------------------------------------------------------------------
# DTFL checkpoint / resume (server state incl. scheduler EMA history)
# ---------------------------------------------------------------------------

def test_dtfl_checkpoint_resume(tmp_path):
    from repro import optim
    from repro.configs.resnet_cifar import RESNET56
    from repro.data.partition import iid_partition
    from repro.data.pipeline import ClientDataset, make_eval_batch
    from repro.data.synthetic import ClassImageTask
    from repro.fed import DTFLTrainer, HeteroEnv, ResNetAdapter, SimClient

    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, 600)
    parts = iid_partition(labels, 3, 0)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(3)]
    ev = make_eval_batch(task, 128)
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)

    path = str(tmp_path / "dtfl.npz")
    tr = DTFLTrainer(adapter, clients, HeteroEnv(3, seed=0), __import__("repro.optim", fromlist=["adam"]).adam(1e-3), seed=0)
    tr.run(3, ev, checkpoint_path=path, checkpoint_every=2)

    tr2 = DTFLTrainer(adapter, clients, HeteroEnv(3, seed=0), __import__("repro.optim", fromlist=["adam"]).adam(1e-3), seed=1)
    tr2.restore(path)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a), np.asarray(b)), tr.params, tr2.params))
    # scheduler observations restored
    assert [c.tier for c in tr.sched.clients] == [c.tier for c in tr2.sched.clients]
    for c1, c2 in zip(tr.sched.clients, tr2.sched.clients):
        assert set(c1.ema) == set(c2.ema)
        for t in c1.ema:
            assert abs(c1.ema[t].value - c2.ema[t].value) < 1e-9
    # and training continues from the restored state
    logs = tr2.run(1, ev)
    assert np.isfinite(logs[-1].acc)


# ---------------------------------------------------------------------------
# dry-run integration (subprocess: needs its own XLA device-count env)
# ---------------------------------------------------------------------------

def test_dryrun_subprocess_single_combo():
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # pin the cpu backend: without it jax probes for a TPU via GCP instance
    # metadata (30 curl retries per variable, ~3 min of wall time before the
    # compile even starts). The 512-device dry-run mesh is a HOST platform
    # flag (xla_force_host_platform_device_count) and works on cpu.
    env["JAX_PLATFORMS"] = "cpu"
    # smollm-360m/train_4k lowers+compiles in ~15 s on a 2-CPU container;
    # the previous whisper-base/long_500k combo ate a 400 s compile timeout.
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-360m",
         "--shape", "train_4k", "--no-save"],
        capture_output=True, text=True, timeout=180, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "lowered + compiled OK" in out.stdout, out.stdout + out.stderr
