"""Sharded federation plane: mesh-sharded cohort programs vs the cohort path.

Equivalence ladder (mirrors PR 1/2's engine equivalence tests):

* a 1-device sim mesh must reproduce the cohort path BIT-FOR-BIT — the
  shard_map routing, on-device psum weighted sums, and the host-side
  ``combine_weighted_sums`` finalize are the same math in the same order;
* an N-device mesh must match within numerical tolerance (the only change
  is the cross-shard reduction order of the psum collective);
* ragged cohorts must pad their client axis to a multiple of the mesh axis
  with exact no-op pad clients (zero batches, all-False mask, weight 0).

The N-device tests skip unless jax sees >=4 devices; CI runs them with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import jax
import numpy as np
import pytest

from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.data.pipeline import ClientDataset
from repro.data.synthetic import ClassImageTask
from repro.fed import (DTFLTrainer, ExecPlan, FedAvgTrainer, HeteroEnv,
                       ResNetAdapter, SimClient)
from repro.fed import cohort as cohort_engine
from repro.launch.mesh import make_sim_mesh

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 4, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4"
)


def build_clients(sizes, batch=16):
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, sum(sizes))
    clients, off = [], 0
    for i, s in enumerate(sizes):
        idx = np.arange(off, off + s)
        off += s
        clients.append(SimClient(i, ClientDataset(task, labels, idx, batch), None))
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)
    return adapter, clients


def make_trainer(adapter, clients, exec_plan, cls=DTFLTrainer, **kw):
    if cls is DTFLTrainer:
        kw.setdefault("scheduler", "dynamic")
    return cls(adapter, clients, HeteroEnv(len(clients), seed=0),
               optim.adam(1e-3), seed=0, exec_plan=exec_plan, **kw)


def run_pair(adapter, clients, plan_a, plan_b, *, rounds=2, cls=DTFLTrainer, **kw):
    a = make_trainer(adapter, clients, plan_a, cls=cls, **kw)
    b = make_trainer(adapter, clients, plan_b, cls=cls, **kw)
    parts = list(range(len(clients)))
    for r in range(rounds):
        ra = a.train_round(r, parts)
        rb = b.train_round(r, parts)
        if cls is DTFLTrainer:
            assert ra[1] == rb[1], f"round {r}: tier assignments diverged"
    return a, b


def leaves_equal(x, y):
    lx, ly = jax.tree.leaves(x), jax.tree.leaves(y)
    assert len(lx) == len(ly)
    for a, b in zip(lx, ly):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def leaves_close(x, y, atol=2e-4, rtol=1e-3):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# 1-device mesh: bit-for-bit vs cohort path
# ---------------------------------------------------------------------------

def test_sharded_1dev_bit_equals_cohort():
    adapter, clients = build_clients([64, 64, 48, 32])
    coh, sh = run_pair(adapter, clients, ExecPlan.cohort(),
                       ExecPlan.sharded(make_sim_mesh(1)))
    leaves_equal(coh.params, sh.params)
    for m in coh.aux:
        leaves_equal(coh.aux[m], sh.aux[m])


def test_sharded_1dev_scheduler_observations_identical():
    adapter, clients = build_clients([64, 48, 32, 16])
    coh, sh = run_pair(adapter, clients, ExecPlan.cohort(),
                       ExecPlan.sharded(make_sim_mesh(1)))
    for c1, c2 in zip(coh.sched.clients, sh.sched.clients):
        assert c1.tier == c2.tier and c1.last_obs_tier == c2.last_obs_tier
        for m in c1.ema:
            assert c1.ema[m].value == pytest.approx(c2.ema[m].value, rel=1e-12)


def test_baseline_sharded_1dev_bit_equals_cohort():
    adapter, clients = build_clients([64, 48, 96])
    coh, sh = run_pair(adapter, clients, ExecPlan.cohort(),
                       ExecPlan.sharded(make_sim_mesh(1)), cls=FedAvgTrainer)
    leaves_equal(coh.params, sh.params)


# ---------------------------------------------------------------------------
# N-device mesh: numerical equivalence + real padding
# ---------------------------------------------------------------------------

@multi_device
def test_sharded_4dev_matches_cohort():
    # 5 clients with ragged batch counts -> pads to 8 columns on a 4-mesh
    adapter, clients = build_clients([64, 64, 48, 32, 16])
    coh, sh = run_pair(adapter, clients, ExecPlan.cohort(),
                       ExecPlan.sharded(make_sim_mesh(4)))
    leaves_close(coh.params, sh.params)
    for m in coh.aux:
        leaves_close(coh.aux[m], sh.aux[m])


@multi_device
def test_baseline_sharded_4dev_matches_cohort():
    adapter, clients = build_clients([64, 48, 96])
    coh, sh = run_pair(adapter, clients, ExecPlan.cohort(),
                       ExecPlan.sharded(make_sim_mesh(4)), cls=FedAvgTrainer)
    leaves_close(coh.params, sh.params)


# ---------------------------------------------------------------------------
# padding policy (no mesh needed: build_cohorts is host-side)
# ---------------------------------------------------------------------------

def test_ragged_cohort_pads_to_mesh_multiple():
    adapter, clients = build_clients([64, 48, 16, 96, 32])  # one tier, 5 clients
    cohorts = cohort_engine.build_cohorts(
        clients, list(range(5)), {k: 0 for k in range(5)}, r=0, local_epochs=1,
        pad_multiple=4,
    )
    (co,) = cohorts
    assert co.size == 5 and co.n_pad == 3
    for name, arr in co.batches.items():
        assert arr.shape[1] == 8 and arr.shape[1] % 4 == 0
        np.testing.assert_array_equal(arr[:, co.size:], 0)  # pad columns zeroed
    assert not co.mask[:, co.size:].any()                   # pads never step
    w = co.client_weights(clients)
    assert w.shape == (8,) and (w[co.size:] == 0).all() and (w[:co.size] > 0).all()


def test_pad_multiple_one_is_identity():
    adapter, clients = build_clients([64, 48])
    a = cohort_engine.build_cohorts(clients, [0, 1], {0: 0, 1: 0}, 0, 1)
    b = cohort_engine.build_cohorts(clients, [0, 1], {0: 0, 1: 0}, 0, 1,
                                    pad_multiple=1)
    (ca,), (cb,) = a, b
    assert cb.n_pad == 0 and ca.mask.shape == cb.mask.shape
    for name in ca.batches:
        np.testing.assert_array_equal(ca.batches[name], cb.batches[name])


def test_execplan_validation():
    with pytest.raises(ValueError):
        ExecPlan(mode="warp")
    with pytest.raises(ValueError):
        ExecPlan(mode="sharded")          # mesh required
    assert ExecPlan.resolve(None).mode == "cohort"
    assert ExecPlan.resolve("loop").mode == "loop"
    plan = ExecPlan.sharded(make_sim_mesh(1))
    assert plan.n_shards == 1 and plan.pad_multiple == 1
    assert ExecPlan.cohort().pad_multiple == 1
    assert "sharded" in plan.describe()
