"""Tier splitting: boundaries, lossless split/merge, cross-arch."""
import jax
import jax.numpy as jnp
import pytest
from hyputil import given, settings, st

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import tiering
from repro.models import model as M


@given(n_layers=st.integers(2, 200), n_modules=st.integers(2, 12))
@settings(max_examples=200, deadline=None)
def test_boundaries_properties(n_layers, n_modules):
    b = tiering.module_boundaries(n_layers, n_modules)
    assert len(b) == n_modules - 1
    assert all(1 <= x <= n_layers - 1 for x in b), b       # both halves non-empty
    assert all(x <= y for x, y in zip(b, b[1:])), b        # monotone
    assert b[-1] >= n_layers - n_layers // (n_modules - 1) - 1


def test_paper_boundaries_resnet_style():
    # 8 modules over 32 layers: tier m keeps ~m/7 of the blocks
    b = tiering.module_boundaries(32, 8)
    assert b[0] < b[3] < b[-1]
    assert b[-1] == 31  # server always keeps at least one block


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_split_merge_roundtrip(arch, key):
    cfg = get_config(arch).reduced().replace(tie_embeddings=False, n_modules=3)
    params = M.init(key, cfg)
    for tier in range(1, tiering.n_tiers(cfg) + 1):
        c, s = tiering.split_params(params, cfg, tier)
        m = tiering.merge_params(c, s)
        assert jax.tree.all(jax.tree.map(jnp.array_equal, params, m)), (arch, tier)


def test_split_forward_equivalence(key):
    """client_forward + server_forward == forward at every tier."""
    cfg = get_config("yi-6b").reduced().replace(
        tie_embeddings=False, dtype="float32", n_modules=3
    )
    params = M.init(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    want, _ = M.forward(params, cfg, batch)
    for tier in range(1, tiering.n_tiers(cfg) + 1):
        c, s = tiering.split_params(params, cfg, tier)
        z, _ = M.client_forward(c, cfg, batch)
        got, _ = M.server_forward(s, cfg, z)
        assert jnp.allclose(want, got, atol=1e-5), tier
