"""Hypothesis shim: use the real library when installed, otherwise turn
``@given`` property tests into skips so the suite still collects and runs.

The container is offline; ``requirements-dev.txt`` declares the optional
dependency for environments that can install it.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in the offline container
    HAVE_HYPOTHESIS = False

    class _DummyStrategy:
        """Absorbs any strategy-building call chain (.map, .flatmap, |, ...)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: _DummyStrategy()

        def __call__(self, *args, **kwargs):
            return _DummyStrategy()

        def __or__(self, other):
            return _DummyStrategy()

    class _Strategies:
        def __getattr__(self, name):
            return lambda *args, **kwargs: _DummyStrategy()

    st = _Strategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco
