"""Algorithm 1 invariants: tier profiling, EMA, T_max assignment."""
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.core.scheduler import DynamicTierScheduler, EMA, StaticScheduler, TierProfile
from repro.core import timemodel


def make_profile(M=7, seed=0):
    rng = np.random.default_rng(seed)
    t_c = np.sort(rng.uniform(1.0, 10.0, M))          # client time grows with tier
    t_s = np.sort(rng.uniform(0.5, 5.0, M))[::-1]     # server time shrinks
    d = np.sort(rng.uniform(1e5, 1e7, M))[::-1]       # transfer shrinks with tier
    return TierProfile(t_client_ref=t_c, t_server_ref=t_s.copy(), d_size=d.copy())


def observe_synthetic(s, profile, speeds, nu=1e6, nb=10):
    for k, cpu in enumerate(speeds):
        tier = s.clients[k].tier
        t_c = profile.t_client_ref[tier] * nb / cpu
        t_com = profile.d_size[tier] * nb / nu
        s.observe(k, tier=tier, total_client_time=t_c + t_com, nu=nu, n_batches=nb)


def test_ema():
    e = EMA(alpha=0.5)
    assert e.update(10.0) == 10.0
    assert e.update(20.0) == 15.0


def test_observe_recovers_compute_time():
    prof = make_profile()
    s = DynamicTierScheduler(prof, n_clients=1, init_tier=3)
    nb, nu = 10, 1e6
    comm = prof.d_size[3] * nb / nu
    s.observe(0, tier=3, total_client_time=5.0 + comm, nu=nu, n_batches=nb)
    assert s.clients[0].ema[3].value == pytest.approx(5.0)


def test_table2_ratio_invariance():
    """Estimates in unobserved tiers follow the profile ratios exactly
    (the paper's Table-2 property). Server path made negligible so the
    client-side estimate is exposed directly."""
    prof = make_profile()
    prof = TierProfile(
        t_client_ref=prof.t_client_ref,
        t_server_ref=np.zeros_like(prof.t_server_ref),
        d_size=np.zeros_like(prof.d_size),
    )
    s = DynamicTierScheduler(prof, n_clients=1, init_tier=2)
    s.observe(0, tier=2, total_client_time=7.0, nu=1e9, n_batches=10)
    est = s.estimate(0)
    want = prof.t_client_ref / prof.t_client_ref[2] * 7.0
    assert np.allclose(est, want, rtol=1e-6)


@given(
    speeds=st.lists(st.floats(0.05, 8.0), min_size=2, max_size=12),
    seed=st.integers(0, 50),
)
@settings(max_examples=60, deadline=None)
def test_schedule_invariants(speeds, seed):
    prof = make_profile(seed=seed)
    s = DynamicTierScheduler(prof, n_clients=len(speeds))
    for _ in range(3):
        assign = s.schedule()
        observe_synthetic(s, prof, speeds)
    assign = s.schedule()
    est = {k: s.estimate(k) for k in range(len(speeds))}
    t_max = max(e.min() for e in est.values())
    for k, m in assign.items():
        # line 33: assigned tier is feasible ...
        assert est[k][m] <= t_max + 1e-9
        # ... and is the LARGEST feasible tier (least offloading)
        higher = np.flatnonzero(est[k] <= t_max + 1e-9)
        assert m == higher.max()
    # straggler bound: the schedule never exceeds T_max
    assert s.round_time(assign) <= t_max + 1e-9


def test_faster_client_gets_higher_tier():
    prof = make_profile(seed=3)
    speeds = [0.1, 8.0]
    s = DynamicTierScheduler(prof, n_clients=2)
    for _ in range(4):
        assign = s.schedule()
        observe_synthetic(s, prof, speeds)
    assign = s.schedule()
    assert assign[1] >= assign[0]


def test_dynamic_adapts_to_profile_change():
    prof = make_profile(seed=1)
    s = DynamicTierScheduler(prof, n_clients=2)
    speeds = [4.0, 4.0]
    for _ in range(3):
        s.schedule()
        observe_synthetic(s, prof, speeds)
    before = s.schedule()[0]
    speeds = [0.05, 4.0]  # client 0 suddenly slow
    for _ in range(4):
        s.schedule()
        observe_synthetic(s, prof, speeds)
    after = s.schedule()[0]
    assert after <= before  # more offloading for the now-slow client


def test_comm_cost_matches_ground_truth_for_any_task_size():
    """Regression (comm-model unit bug): the profile used to bake a
    reference n_batches into one per-batch d_size, overcounting the
    parameter download by nb/nb_ref for clients whose task size differs.
    With z/param bytes stored separately, the scheduler's comm term and
    estimate equal ``timemodel.simulate_client_times`` ground truth for
    EVERY batch count."""
    from repro.configs.resnet_cifar import RESNET110

    costs = timemodel.resnet_tier_costs(RESNET110, 32)
    prof = TierProfile.from_cost_table(
        costs, ref_flops=timemodel.UNIT_FLOPS,
        server_flops=timemodel.SERVER_FLOPS)
    rp = timemodel.PAPER_PROFILES[2]   # 1 CPU / 30 Mbps
    s = DynamicTierScheduler(prof, n_clients=1)
    for tier in (0, 3, 6):
        for nb in (1, 4, 10, 37):      # the paper's "varying task sizes"
            t = timemodel.simulate_client_times(costs, tier, rp, nb)
            s.observe(0, tier=tier, total_client_time=t["client"] + t["comm"],
                      nu=rp.bytes_per_s, n_batches=nb)
            # line 22 must recover the pure compute time exactly...
            assert s.clients[0].ema[tier].value == pytest.approx(
                t["client"], rel=1e-9)
            # ...so the Eq.-5 estimate for the observed tier equals ground
            # truth (server term matches at n_sharing=1)
            est = s.estimate(0)
            assert est[tier] == pytest.approx(t["total"], rel=1e-6)
            s.clients[0].ema.clear()   # independent observations


def test_legacy_d_size_profile_still_composes_per_batch():
    prof = TierProfile(t_client_ref=np.arange(1.0, 4.0),
                       t_server_ref=np.zeros(3), d_size=np.full(3, 100.0))
    np.testing.assert_array_equal(prof.z_bytes, np.full(3, 100.0))
    np.testing.assert_array_equal(prof.param_bytes, np.zeros(3))
    assert prof.comm_bytes(1, 7) == 700.0


def test_static_scheduler():
    s = StaticScheduler(tier=2, n_clients=4)
    assert s.schedule() == {0: 2, 1: 2, 2: 2, 3: 2}


def test_scheduler_beats_static_on_heterogeneous_pool():
    """Headline property: dynamic tiering's straggler time <= any static tier."""
    full_cfg_costs = None
    prof = make_profile(seed=7)
    speeds = [4.0, 2.0, 1.0, 0.2, 0.1]
    dyn = DynamicTierScheduler(prof, n_clients=5)
    for _ in range(5):
        dyn.schedule()
        observe_synthetic(dyn, prof, speeds)
    assign = dyn.schedule()
    t_dyn = dyn.round_time(assign)

    def static_time(m):
        return max(dyn.estimate(k)[m] for k in range(5))

    assert t_dyn <= min(static_time(m) for m in range(prof.n_tiers)) + 1e-9


# ---------------------------------------------------------------------------
# PairingScheduler (mutual offload, arxiv 2308.13849)
# ---------------------------------------------------------------------------

from itertools import permutations

from repro.core.scheduler import (PairingScheduler, _greedy_pairs,
                                  _hungarian_pairs)
from repro.core.topology import SERVER, Assignment, OffloadTopology


def _brute_force_total(C):
    n = C.shape[0]
    return min(sum(C[i, j] for i, j in enumerate(p))
               for p in permutations(range(n)))


def _matching_total(C, pairs):
    assert sorted(g for g, _ in pairs) == list(range(C.shape[0]))
    assert sorted(h for _, h in pairs) == list(range(C.shape[0]))
    return sum(C[g, h] for g, h in pairs)


def _observed_pairing(speeds, *, seed=0, method="hungarian", rounds=3):
    """A PairingScheduler that has observed ``speeds`` for a few rounds."""
    prof = make_profile(seed=seed)
    s = PairingScheduler(prof, n_clients=len(speeds), method=method)
    for _ in range(rounds):
        s.schedule()
        observe_synthetic(s, prof, speeds)
    return s


def test_hungarian_matches_bruteforce_small_instances():
    """<=6-client instances: the Hungarian matching achieves the brute-force
    minimum total pair cost (3x3 matrices = 6 clients and under)."""
    for seed in range(25):
        rng = np.random.default_rng(seed)
        for n in (1, 2, 3):
            C = rng.uniform(1.0, 10.0, (n, n))
            got = _matching_total(C, _hungarian_pairs(C))
            assert got == pytest.approx(_brute_force_total(C), rel=1e-12)


def test_hungarian_matches_bruteforce_larger():
    for seed in range(8):
        C = np.random.default_rng(100 + seed).uniform(0.1, 50.0, (5, 5))
        got = _matching_total(C, _hungarian_pairs(C))
        assert got == pytest.approx(_brute_force_total(C), rel=1e-12)


def test_greedy_within_bounded_factor():
    """Slowest-guest-first greedy stays within 3x of the optimal matching on
    a deterministic battery of random instances (and is a valid matching)."""
    for seed in range(30):
        rng = np.random.default_rng(1000 + seed)
        n = int(rng.integers(2, 7))
        C = rng.uniform(1.0, 10.0, (n, n))
        greedy = _matching_total(C, _greedy_pairs(C))
        best = _brute_force_total(C)
        assert best <= greedy + 1e-12
        assert greedy <= 3.0 * best


def test_pairing_never_observed_falls_back_to_server():
    prof = make_profile()
    s = PairingScheduler(prof, n_clients=6)
    out = s.schedule()
    assert all(isinstance(a, Assignment) for a in out.values())
    assert all(a.host == SERVER for a in out.values())
    assert s.last_hosts == {}
    # ... and the tiers equal the plain Algorithm-1 schedule
    dyn = DynamicTierScheduler(prof, n_clients=6)
    assert {k: a.tier for k, a in out.items()} == dyn.schedule()


@pytest.mark.parametrize("speed", [4.0, 0.1])
def test_pairing_homogeneous_cohort_falls_back_to_server(speed):
    """All-fast and all-slow cohorts have nothing to gain from pairing."""
    s = _observed_pairing([speed] * 6)
    out = s.schedule()
    assert all(a.host == SERVER for a in out.values())
    assert s.last_hosts == {}


def test_pairing_matches_fast_hosts_with_slow_guests():
    speeds = [8.0, 6.0, 4.0, 0.3, 0.2, 0.1]
    s = _observed_pairing(speeds)
    out = s.schedule()
    hosts = {a.host for a in out.values() if a.host != SERVER}
    guests = {k for k, a in out.items() if a.host != SERVER}
    assert guests, "spread cohort must produce at least one pair"
    assert hosts <= {0, 1, 2}          # hosts come from the fast half
    assert guests <= {3, 4, 5}         # guests from the slow half
    assert not (hosts & guests)        # a host is never itself a guest
    for k, a in out.items():
        assert 0 <= a.tier < s.profile.n_tiers
    assert s.last_hosts == {k: out[k].host for k in guests}


def test_pairing_odd_cohort_leaves_middle_on_server():
    speeds = [8.0, 6.0, 0.2, 0.15, 0.1]
    s = _observed_pairing(speeds)
    out = s.schedule()
    paired = [k for k, a in out.items() if a.host != SERVER]
    assert len(paired) <= 2            # floor(5/2) pairs at most
    assert len(out) - len(paired) >= 3  # hosts + the odd one stay on SERVER


def test_pairing_greedy_method_and_bad_method():
    s = _observed_pairing([8.0, 6.0, 0.2, 0.1], method="greedy")
    out = s.schedule()
    assert any(a.host != SERVER for a in out.values())
    with pytest.raises(ValueError, match="greedy"):
        PairingScheduler(make_profile(), 4, method="nope")


def test_engine_adapter_widens_narrow_schedules():
    """Satellite: static/dynamic schedule() keeps its narrow cid->tier dict;
    the ONE widening point is OffloadTopology.from_schedule."""
    prof = make_profile()
    for sched in (StaticScheduler(tier=2, n_clients=4),
                  DynamicTierScheduler(prof, n_clients=4)):
        narrow = sched.schedule()
        assert all(isinstance(v, int) for v in narrow.values())
        topo = OffloadTopology.from_schedule(narrow)
        assert topo.is_server_only
        assert topo.tiers() == narrow
        assert topo.hosts() == {k: SERVER for k in narrow}
    # and the generalized dict widens losslessly too
    wide = OffloadTopology.from_schedule({0: (3, SERVER), 1: (1, 0)})
    assert not wide.is_server_only
    assert wide.tiers() == {0: 3, 1: 1}
    assert wide.guests_of() == {0: [1]}


def test_pairing_profile_has_server_speedup():
    from repro.configs.resnet_cifar import RESNET110

    costs = timemodel.resnet_tier_costs(RESNET110, 32)
    prof = TierProfile.from_cost_table(
        costs, ref_flops=timemodel.UNIT_FLOPS,
        server_flops=timemodel.SERVER_FLOPS)
    assert prof.server_speedup == pytest.approx(
        timemodel.SERVER_FLOPS / timemodel.UNIT_FLOPS)
    # legacy construction defaults to the global ratio
    assert make_profile().server_speedup == pytest.approx(
        timemodel.SERVER_FLOPS / timemodel.UNIT_FLOPS)
