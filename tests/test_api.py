"""The declarative experiment API: spec validation, serialization, golden
back-compat vs the pre-spec direct wiring, checkpoint spec-stamping, the
registries' extension story, and the sweep plane."""
from __future__ import annotations

import numpy as np
import pytest

from repro import presets, registry
from repro.api import (CheckpointSpec, ChurnSpec, CodecSpec, DataSpec,
                       EngineSpec, ExperimentSpec, Federation, SpecError,
                       TrainerSpec)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(presets.PRESETS))
def test_preset_json_roundtrip(name):
    spec = presets.PRESETS[name]()
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert back.spec_hash() == spec.spec_hash()
    assert back.program_key() == spec.program_key()


def test_unknown_fields_rejected():
    with pytest.raises(SpecError, match="unknown field.*spec.bogus"):
        ExperimentSpec.from_dict({"bogus": 1})
    with pytest.raises(SpecError, match="spec.trainer.lrr"):
        ExperimentSpec.from_dict({"trainer": {"lrr": 0.1}})
    with pytest.raises(SpecError, match="spec.engine.churn.dropp"):
        ExperimentSpec.from_dict(
            {"engine": {"name": "events", "churn": {"dropp": 0.5}}})


def test_identity_hash_excludes_run_length_knobs():
    s = presets.quickstart()
    assert s.with_overrides({"rounds": 99}).spec_hash() == s.spec_hash()
    assert s.with_overrides({"target_acc": 0.9}).spec_hash() == s.spec_hash()
    assert s.with_overrides(
        {"checkpoint.path": "/tmp/x.npz"}).spec_hash() == s.spec_hash()
    assert s.with_overrides({"seed": 7}).spec_hash() != s.spec_hash()
    assert s.with_overrides({"trainer.lr": 0.5}).spec_hash() != s.spec_hash()


def test_with_overrides_parses_and_revalidates():
    s = presets.quickstart()
    s2 = s.with_overrides({"trainer.method": "fedavg", "rounds": "7",
                           "data.iid": "false"})
    assert (s2.trainer.method, s2.rounds, s2.data.iid) == ("fedavg", 7, False)
    with pytest.raises(SpecError):
        s.with_overrides({"trainer.method": "nope"})


# ---------------------------------------------------------------------------
# spec-time validation: names + illegal combos, with the legal set in errors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad, match", [
    (dict(trainer=dict(method="dynmaic")), "registered trainers"),
    (dict(trainer=dict(scheduler="dynmaic")), "registered schedulers"),
    (dict(codec=dict(name="zip9")), "registered codecs"),
    (dict(exec=dict(mode="warp")), "registered exec mode"),
    (dict(engine=dict(name="asink")), "registered engines"),
    (dict(data=dict(dataset="imagenet")), "registered datasets"),
    (dict(model=dict(arch="resnet-13")), "registered archs"),
    (dict(env=dict(profiles="fast")), "registered profile pool"),
])
def test_unknown_names_list_choices(bad, match):
    with pytest.raises(SpecError, match=match):
        ExperimentSpec.from_dict(bad)


def test_illegal_combos_rejected_at_spec_time():
    # fedgkt + codec: the KD protocol is not the codec wire contract
    with pytest.raises(SpecError, match="fedgkt.*wire compression"):
        ExperimentSpec(trainer=TrainerSpec(method="fedgkt"),
                       codec=CodecSpec("int8"))
    with pytest.raises(SpecError, match="splitfed"):
        ExperimentSpec(trainer=TrainerSpec(method="splitfed"),
                       codec=CodecSpec("topk0.1"))
    # ... but identity-class codecs stay legal for them
    ExperimentSpec(trainer=TrainerSpec(method="fedgkt"),
                   codec=CodecSpec("none"))
    # churn needs an event-driven engine
    with pytest.raises(SpecError, match="churn requires"):
        ExperimentSpec(engine=EngineSpec(churn=ChurnSpec()))
    # resume + async / resume + churn
    with pytest.raises(SpecError, match="resume supports"):
        ExperimentSpec(engine=EngineSpec(name="async"),
                       checkpoint=CheckpointSpec(resume="x.npz"))
    with pytest.raises(SpecError, match="resume supports"):
        ExperimentSpec(trainer=TrainerSpec(method="fedat"),
                       checkpoint=CheckpointSpec(resume="x.npz"))
    with pytest.raises(SpecError, match="churn"):
        ExperimentSpec(engine=EngineSpec(name="events", churn=ChurnSpec()),
                       checkpoint=CheckpointSpec(resume="x.npz"))
    # async engine needs an async-faithful trainer
    with pytest.raises(SpecError, match="fedyogi.*async"):
        ExperimentSpec(trainer=TrainerSpec(method="fedyogi"),
                       engine=EngineSpec(name="async"))
    # scheduler is a tier-scheduling (dtfl) knob
    with pytest.raises(SpecError, match="tier-scheduling"):
        ExperimentSpec(trainer=TrainerSpec(method="fedavg", scheduler=2))
    # arch kind <-> data kind
    with pytest.raises(SpecError, match="needs a lm dataset"):
        ExperimentSpec.from_dict({"model": {"arch": "smollm-360m"}})
    with pytest.raises(SpecError, match="needs a image dataset"):
        ExperimentSpec.from_dict({"data": {"dataset": "lm"}})


def test_bare_parameterized_family_names_rejected():
    """'topk' / 'static' are family names, not specs — they must fail at
    validation time, not crash inside a build with a raw ValueError."""
    with pytest.raises(SpecError, match="registered codecs"):
        ExperimentSpec(codec=CodecSpec("topk"))
    with pytest.raises(SpecError, match="registered schedulers"):
        ExperimentSpec(trainer=TrainerSpec(scheduler="static"))
    with pytest.raises(registry.RegistryError):
        registry.codecs.validate("topk")
    with pytest.raises(registry.RegistryError):
        registry.schedulers.validate("static")


def test_table4_accuracy_honors_method():
    assert presets.table4_accuracy(10, "fedavg").trainer.method == "fedavg"
    assert presets.table4_accuracy(10, "dtfl").trainer.method == "dtfl"


def test_with_overrides_creates_churn_group():
    s = presets.quickstart().with_overrides(
        {"engine.name": "events", "engine.churn.drop": 0.2})
    assert s.engine.churn is not None and s.engine.churn.drop == 0.2
    # ...and the combo rules still apply to the created group
    with pytest.raises(SpecError, match="churn requires"):
        presets.quickstart().with_overrides({"engine.churn.drop": 0.2})


def test_scheduler_specs_canonicalized():
    assert ExperimentSpec(trainer=TrainerSpec(scheduler="3")).trainer.scheduler == 3
    assert ExperimentSpec(
        trainer=TrainerSpec(scheduler="dynamic:2")).trainer.scheduler == "dynamic:2"
    assert CodecSpec("none").name == "identity"
    assert CodecSpec("  TOPK0.05 ").name == "topk0.05"


def test_registry_metadata_matches_class_attributes():
    """The registry's static supports_* metadata must not drift from the
    trainer classes (spec validation trusts the registry)."""
    for name in registry.trainers.names():
        meta = registry.trainers.meta(name)
        cls = registry.trainers.load(name)
        assert meta["supports_async"] == getattr(cls, "supports_async", True), name
        assert meta["supports_codec"] == getattr(cls, "supports_codec", True), name
        assert cls.name == name


def test_assigned_arch_names_match_configs():
    from repro.configs import ASSIGNED_ARCHS

    assert set(registry.ASSIGNED_ARCH_NAMES) == set(ASSIGNED_ARCHS)


def test_train_py_rejects_bad_knobs_at_parse_time(capsys):
    from repro.launch.train import main

    for argv in (["--scheduler", "dynmaic"], ["--codec", "zip9"],
                 ["--method", "fedsgd"], ["--exec", "warp"],
                 ["--engine", "asink"], ["--dataset", "imagenet"]):
        with pytest.raises(SystemExit):
            main(argv)
        err = capsys.readouterr().err
        assert "registered" in err, argv
    # illegal combo -> argparse error carrying the SpecError text
    with pytest.raises(SystemExit):
        main(["--churn"])
    assert "churn requires" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# golden back-compat: flag vector -> bit-identical RoundLog streams through
# the spec path vs commit f781a4b's direct wiring (replicated inline)
# ---------------------------------------------------------------------------

def _old_direct_wiring(method: str, engine: str, n_clients=4, samples=400,
                       rounds=2):
    """Commit f781a4b's launch/train.py wiring, verbatim (defaults:
    --arch resnet-56 --dataset cifar10 --batch-size 32 --scheduler dynamic
    --exec cohort --codec identity --switch-every 50 --seed 0 --lr 1e-3)."""
    from repro import optim
    from repro.configs.resnet_cifar import get_resnet
    from repro.data.partition import dirichlet_partition
    from repro.data.pipeline import ClientDataset, make_eval_batch
    from repro.data.synthetic import DATASETS, ClassImageTask
    from repro.fed import (ExecPlan, HeteroEnv, ResNetAdapter, SimClient,
                           TRAINERS)

    full_cfg = get_resnet("resnet-56")
    cfg = full_cfg.reduced()
    adapter = ResNetAdapter(cfg, cost_cfg=full_cfg, dcor_alpha=0.0)
    base = DATASETS["cifar10"]
    task = ClassImageTask(n_classes=base.n_classes, image_size=cfg.image_size,
                          noise=base.noise, seed=base.seed)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, task.n_classes, samples)
    parts = dirichlet_partition(labels, n_clients, seed=0)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(n_clients)]
    eval_batch = make_eval_batch(task, 512)
    env = HeteroEnv(n_clients, switch_every=50, seed=0)
    kw = {"scheduler": "dynamic"} if method == "dtfl" else {}
    kw["exec_plan"] = ExecPlan.from_flags("cohort", devices=None)
    kw["codec"] = "identity"
    trainer = TRAINERS[method](adapter, clients, env, optim.adam(1e-3),
                               seed=0, **kw)
    logs = trainer.run(rounds, eval_batch, target_acc=None, participation=1.0,
                       verbose=False, churn=None, engine=engine)
    return logs, trainer


def _flag_vector_spec(method: str, engine: str, n_clients=4, samples=400,
                      rounds=2) -> ExperimentSpec:
    from repro.launch.train import build_parser, spec_from_args

    argv = ["--method", method, "--engine", engine, "--clients", str(n_clients),
            "--samples", str(samples), "--rounds", str(rounds)]
    return spec_from_args(build_parser().parse_args(argv))


@pytest.mark.parametrize("method", ["dtfl", "fedavg"])
@pytest.mark.parametrize("engine", ["rounds", "events"])
def test_golden_backcompat_bit_exact(method, engine):
    import jax

    old_logs, old_tr = _old_direct_wiring(method, engine)
    fed = _flag_vector_spec(method, engine).build()
    new_logs = fed.run()
    assert len(old_logs) == len(new_logs)
    for a, b in zip(old_logs, new_logs):
        assert (a.round, a.clock, a.acc, a.assignment, a.straggler,
                a.uplink_bytes) == (b.round, b.clock, b.acc, b.assignment,
                                    b.straggler, b.uplink_bytes)
    same = jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()),
        old_tr.params, fed.trainer.params)
    assert all(jax.tree.leaves(same))


# ---------------------------------------------------------------------------
# spec-stamped checkpoints: resume verifies the experiment identity
# ---------------------------------------------------------------------------

def _tiny_spec(**over):
    spec = ExperimentSpec(
        data=DataSpec(clients=3, samples=96, batch_size=16, iid=True,
                      eval_size=128),
        rounds=2)
    return spec.with_overrides(over) if over else spec


def test_resume_verifies_spec_stamp(tmp_path):
    path = str(tmp_path / "state.npz")
    spec = _tiny_spec(**{"checkpoint.path": path, "checkpoint.every": 1})
    fed = spec.build()
    logs = fed.run()

    # same experiment, larger budget: resumes and continues the round count
    cont = spec.with_overrides({"rounds": 3, "checkpoint.resume": path})
    logs2 = cont.build().run()
    assert [l.round for l in logs2] == [2]
    assert logs2[0].clock > logs[-1].clock

    # different experiment identity: rejected with both hashes in the error
    other = spec.with_overrides({"trainer.lr": 5e-3,
                                 "checkpoint.resume": path})
    with pytest.raises(SpecError, match="different experiment"):
        other.build().run()
    # Federation.resume() is the facade-level equivalent
    with pytest.raises(SpecError, match="spec hash"):
        other.build().resume(path)


def test_resume_continuation_is_bit_deterministic(tmp_path):
    path = str(tmp_path / "state.npz")
    full = _tiny_spec(rounds=4).build().run()
    ck = _tiny_spec(**{"rounds": 2, "checkpoint.path": path,
                       "checkpoint.every": 2}).build()
    ck.run()
    rest = _tiny_spec(**{"rounds": 4, "checkpoint.resume": path}).build().run()
    tail = full[2:]
    assert [l.round for l in rest] == [l.round for l in tail]
    for a, b in zip(rest, tail):
        assert (a.clock, a.acc, a.straggler) == (b.clock, b.acc, b.straggler)


# ---------------------------------------------------------------------------
# registry extension story: a new codec + scheduler, end to end
# ---------------------------------------------------------------------------

def test_register_custom_codec_and_scheduler_end_to_end():
    from repro.core.codec import Codec

    class NoopCodec(Codec):
        name = "noop"

    registry.register_codec("noop", build=lambda spec: NoopCodec(),
                            identity=True)

    def build_lowest(spec, *, profile, n_clients, n_tiers):
        from repro.core.scheduler import StaticScheduler

        return StaticScheduler(n_tiers - 1, n_clients)

    registry.register_scheduler("lowest", build=build_lowest)
    try:
        spec = _tiny_spec(**{"codec.name": "noop",
                             "trainer.scheduler": "lowest"})
        assert spec.codec.name == "noop" and spec.codec.is_identity
        fed = spec.build()
        logs = fed.run()
        assert len(logs) == 2
        # the custom scheduler pinned everyone to the lowest tier
        assert set(logs[-1].assignment.values()) == {fed.adapter.n_tiers - 1}
        # identity-class custom codecs pass the supports_codec gate
        _tiny_spec(**{"codec.name": "noop", "trainer.method": "fedgkt"})
    finally:
        registry.codecs.unregister("noop")
        registry.schedulers.unregister("lowest")
    with pytest.raises(SpecError):
        _tiny_spec(**{"codec.name": "noop"})


# ---------------------------------------------------------------------------
# sweep plane
# ---------------------------------------------------------------------------

def test_sweep_grid_expansion():
    from benchmarks.sweep import expand, parse_grid

    axes = parse_grid("trainer.method=dtfl,fedavg; data.clients=3,4")
    assert [a[0] for a in axes] == ["trainer.method", "data.clients"]
    points = expand(presets.quickstart(), axes)
    assert len(points) == 4
    combos = {(s.trainer.method, s.data.clients) for _, s in points}
    assert combos == {("dtfl", 3), ("dtfl", 4), ("fedavg", 3), ("fedavg", 4)}
    with pytest.raises(SpecError):
        expand(presets.quickstart(), parse_grid("trainer.method=dtfl,nope"))
    with pytest.raises(SpecError):
        parse_grid("rounds")


def test_sweep_runs_and_reuses_programs():
    from benchmarks.sweep import main

    rows = main(emit_fn=lambda s: None, preset="quickstart",
                grid="data.clients=2,3", rounds=1)
    header, body = rows[0], rows[1:]
    assert header[-1] == "programs_reused"
    assert len(body) == 2
    # same program key across the grid -> the second point adopts the
    # first's compiled programs
    assert [r[-1] for r in body] == [False, True]


def test_program_key_tracks_compiled_closures():
    s = presets.quickstart()
    assert s.with_overrides({"data.clients": 9}).program_key() == s.program_key()
    assert s.with_overrides({"seed": 3}).program_key() == s.program_key()
    for path, val in (("trainer.lr", 0.5), ("codec.name", "int8"),
                      ("trainer.method", "fedavg"), ("exec.mode", "loop"),
                      ("data.batch_size", 16), ("trainer.dcor_alpha", 0.1)):
        assert s.with_overrides({path: val}).program_key() != s.program_key(), path
