"""The roofline extractor: trip-count awareness, collective accounting."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_counted():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    txt = _compile(lambda x, w: x @ w, x, w)
    a = H.analyze(txt)
    assert a["flops"] == pytest.approx(2 * 64 * 128 * 256, rel=0.01)


@pytest.mark.parametrize("L", [2, 4, 8])
def test_scan_trip_count_scaling(L):
    w = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        return jax.lax.scan(body, x, w)[0].sum()

    a = H.analyze(_compile(f, w, x))
    assert a["flops"] == pytest.approx(L * 2 * 32 * 128 * 128, rel=0.05)


def test_flat_cost_analysis_undercounts_but_extractor_does_not():
    """Documents the while-body-once behaviour the extractor exists to fix."""
    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        return jax.lax.scan(body, x, w)[0].sum()

    w8 = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    w2 = jax.ShapeDtypeStruct((2, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    c8 = jax.jit(f).lower(w8, x).compile()
    c2 = jax.jit(f).lower(w2, x).compile()
    # flat_cost_analysis normalizes the list|dict|None return across jax versions
    ca8, ca2 = H.flat_cost_analysis(c8), H.flat_cost_analysis(c2)
    assert ca8["flops"] == ca2["flops"]  # the bug
    a8 = H.analyze(c8.as_text())
    a2 = H.analyze(c2.as_text())
    assert a8["flops"] == pytest.approx(4 * a2["flops"], rel=0.05)     # the fix


def test_shape_bytes():
    assert H._shape_bytes("f32[2,3]{1,0}") == 24
    assert H._shape_bytes("bf16[128]") == 256
    assert H._shape_bytes("(f32[2], s32[4])") == 24
    assert H._shape_bytes("pred[]") == 1


def test_roofline_terms_dominance():
    t = H.roofline_terms({"flops": 197e12, "bytes": 1.0, "collective_bytes_total": 1.0})
    assert t["dominant"] == "compute"
    t = H.roofline_terms({"flops": 1.0, "bytes": 819e9 * 5, "collective_bytes_total": 1.0})
    assert t["dominant"] == "memory"
