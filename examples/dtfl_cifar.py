"""End-to-end driver: the paper's main experiment, scaled for CPU.

The ``presets.cifar_paper`` scenario: ResNet on the CIFAR-shaped synthetic
task, 10 heterogeneous clients, dynamic tier scheduling, non-IID
Dirichlet(0.5) partition, profile switching — DTFL vs FedAvg simulated
time-to-accuracy, one method override apart.

    PYTHONPATH=src python examples/dtfl_cifar.py [--rounds 12]
"""
import argparse

from repro import presets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--target", type=float, default=0.7)
    args = ap.parse_args()

    results = {}
    for method in ("dtfl", "fedavg"):
        spec = presets.cifar_paper(method, rounds=args.rounds,
                                   clients=args.clients, target=args.target)
        logs = spec.build().run(verbose=True)
        results[method] = logs
        print(f"== {method}: acc={logs[-1].acc:.3f} sim_time={logs[-1].clock:,.0f}s "
              f"rounds={len(logs)}")

    speedup = results["fedavg"][-1].clock / max(results["dtfl"][-1].clock, 1e-9)
    print(f"\nDTFL vs FedAvg simulated speedup: {speedup:.1f}x "
          f"(paper reports ~5x on ResNet-110 CIFAR-10)")


if __name__ == "__main__":
    main()
