"""End-to-end driver: the paper's main experiment, scaled for CPU.

Trains the ResNet on the CIFAR-shaped synthetic task with 10 heterogeneous
clients, dynamic tier scheduling, non-IID Dirichlet(0.5) partition, profile
switching — then compares the simulated time-to-accuracy against FedAvg.

    PYTHONPATH=src python examples/dtfl_cifar.py [--rounds 12]
"""
import argparse

import numpy as np

from repro import optim
from repro.configs.resnet_cifar import RESNET56, RESNET110
from repro.data.partition import dirichlet_partition
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import DTFLTrainer, FedAvgTrainer, HeteroEnv, ResNetAdapter, SimClient


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--target", type=float, default=0.7)
    args = ap.parse_args()

    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(0, 10, 3000)
    parts = dirichlet_partition(labels, args.clients, 0.5, seed=1)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(args.clients)]
    ev = make_eval_batch(task, 512)
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET110)  # times priced full-size

    results = {}
    for name, cls in (("dtfl", DTFLTrainer), ("fedavg", FedAvgTrainer)):
        env = HeteroEnv(args.clients, switch_every=5, seed=0)
        tr = cls(adapter, clients, env, optim.adam(1e-3), seed=0)
        logs = tr.run(args.rounds, ev, target_acc=args.target, verbose=True)
        results[name] = logs
        print(f"== {name}: acc={logs[-1].acc:.3f} sim_time={logs[-1].clock:,.0f}s "
              f"rounds={len(logs)}")

    speedup = results["fedavg"][-1].clock / max(results["dtfl"][-1].clock, 1e-9)
    print(f"\nDTFL vs FedAvg simulated speedup: {speedup:.1f}x "
          f"(paper reports ~5x on ResNet-110 CIFAR-10)")


if __name__ == "__main__":
    main()
