"""Privacy integration (paper §4.4): distance-correlation regularized DTFL.

Trains the ``presets.table5`` scenario with alpha in {0, 0.5}; reports the
accuracy cost and the achieved DCor(x, z) reduction — lower DCor means the
uploaded activations reveal less about the raw inputs. The Federation
facade exposes the built adapter/trainer, so the probe reads the trained
client half directly.

    PYTHONPATH=src python examples/privacy_dcor.py
"""
import jax.numpy as jnp

from repro import presets
from repro.data.pipeline import make_eval_batch
from repro.models import resnet as R
from repro.privacy import dcor


def main():
    for alpha in (0.0, 0.5):
        fed = presets.table5(alpha, rounds=6).with_overrides(
            {"data.clients": 4}).build()
        logs = fed.run()
        # probe on the same synthetic task the clients trained on
        task = fed.clients[0].dataset.task
        x = jnp.asarray(make_eval_batch(task, 128)["images"])
        cp, _ = fed.adapter.split(fed.trainer.params, 1)
        z = R.client_forward(cp, fed.adapter.cfg, x)
        leak = float(dcor(x, z))
        print(f"alpha={alpha}: acc={logs[-1].acc:.3f}  DCor(x, z)={leak:.3f}")
    print("higher alpha => lower DCor (less leakage) at a small accuracy cost")


if __name__ == "__main__":
    main()
