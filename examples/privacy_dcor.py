"""Privacy integration (paper §4.4): distance-correlation regularized DTFL.

Trains with alpha in {0, 0.5}; reports the accuracy cost and the achieved
DCor(x, z) reduction — lower DCor means the uploaded activations reveal less
about the raw inputs.

    PYTHONPATH=src python examples/privacy_dcor.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.data.partition import iid_partition
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import DTFLTrainer, HeteroEnv, ResNetAdapter, SimClient
from repro.models import resnet as R
from repro.privacy import dcor


def main():
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size, noise=1.0)
    labels = np.random.default_rng(0).integers(0, 10, 1200)
    parts = iid_partition(labels, 4, 0)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(4)]
    ev = make_eval_batch(task, 512)

    probe = make_eval_batch(task, 128)
    x = jnp.asarray(probe["images"])

    for alpha in (0.0, 0.5):
        adapter = ResNetAdapter(cfg, cost_cfg=RESNET56, dcor_alpha=alpha)
        tr = DTFLTrainer(adapter, clients, HeteroEnv(4, seed=0), optim.adam(1e-3), seed=0)
        logs = tr.run(6, ev)
        cp, _ = adapter.split(tr.params, 1)
        z = R.client_forward(cp, cfg, x)
        leak = float(dcor(x, z))
        print(f"alpha={alpha}: acc={logs[-1].acc:.3f}  DCor(x, z)={leak:.3f}")
    print("higher alpha => lower DCor (less leakage) at a small accuracy cost")


if __name__ == "__main__":
    main()
