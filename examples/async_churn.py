"""Event-engine tour: sync vs async tiers under client churn.

Runs the same 8-client DTFL setup three ways on the reduced ResNet —
legacy synchronous rounds, the discrete-event engine with churn (mid-round
dropouts, arrivals, profile switches), and FedAT-style async tiers — and
prints each mode's virtual-clock / accuracy trajectory.

  PYTHONPATH=src:. python examples/async_churn.py --rounds 6
"""
from __future__ import annotations

import argparse

import numpy as np

from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.data.partition import iid_partition
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import (ChurnModel, DTFLTrainer, HeteroEnv, ResNetAdapter,
                       SimClient)


def build(n_clients: int, seed: int = 0):
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(seed).integers(0, 10, 1600)
    parts = iid_partition(labels, n_clients, seed)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(n_clients)]
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56)
    return adapter, clients, make_eval_batch(task, 256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--n-groups", type=int, default=2)
    args = ap.parse_args()

    for mode, run_kw in (
        ("rounds (legacy sync)", dict(engine="rounds")),
        ("events + churn", dict(
            engine="events",
            churn=ChurnModel(args.clients, drop_prob=0.15, switch_prob=0.15,
                             start_offline_frac=0.25, seed=1))),
        ("async tiers", dict(engine="async", n_groups=args.n_groups)),
    ):
        adapter, clients, ev = build(args.clients)
        tr = DTFLTrainer(adapter, clients, HeteroEnv(args.clients, seed=0),
                         optim.adam(1e-3), seed=0)
        logs = tr.run(args.rounds, ev, **run_kw)
        last = logs[-1]
        print(f"\n== {mode} ==")
        for l in logs:
            print(f"  step={l.round:<3d} clock={l.clock:9.1f}s acc={l.acc:.3f}")
        print(f"  -> {len(logs)} steps, final clock {last.clock:,.0f}s acc {last.acc:.3f}")


if __name__ == "__main__":
    main()
