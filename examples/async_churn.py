"""Event-engine tour: sync vs async tiers under client churn.

Runs the same 8-client DTFL scenario (``presets.async_churn``) three ways —
legacy synchronous rounds, the discrete-event engine with churn (mid-round
dropouts, arrivals, profile switches), and FedAT-style async tiers — each a
one-field override of the same spec, and prints each mode's virtual-clock /
accuracy trajectory.

  PYTHONPATH=src:. python examples/async_churn.py --rounds 6
"""
from __future__ import annotations

import argparse

from repro import presets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--n-groups", type=int, default=2)
    args = ap.parse_args()

    base = dict(clients=args.clients, rounds=args.rounds,
                n_groups=args.n_groups)
    for mode, spec in (
        ("rounds (legacy sync)", presets.async_churn(engine="rounds", **base)),
        ("events + churn", presets.async_churn(engine="events", churn=True,
                                               **base)),
        ("async tiers", presets.async_churn(engine="async", **base)),
    ):
        logs = spec.build().run()
        last = logs[-1]
        print(f"\n== {mode} ==")
        for l in logs:
            print(f"  step={l.round:<3d} clock={l.clock:9.1f}s acc={l.acc:.3f}")
        print(f"  -> {len(logs)} steps, final clock {last.clock:,.0f}s acc {last.acc:.3f}")


if __name__ == "__main__":
    main()
