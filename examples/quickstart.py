"""Quickstart: one DTFL round, by hand, on the paper's ResNet-56 (reduced).

Shows the full mechanics in ~60 lines: tier scheduling, split, parallel
local-loss updates, merge, FedAvg aggregation.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.core import aggregation, timemodel
from repro.core.scheduler import DynamicTierScheduler, TierProfile
from repro.data.synthetic import ClassImageTask
from repro.models import resnet as R

cfg = RESNET56.reduced()
key = jax.random.PRNGKey(0)
opt = optim.adam(1e-3)

# --- global model + tier profiling (server side, done once) -----------------
params = R.init(key, cfg)
costs = timemodel.resnet_tier_costs(RESNET56, batch_size=32)  # priced full-size
profile = TierProfile.from_cost_table(
    costs, ref_flops=timemodel.UNIT_FLOPS,
    server_flops=timemodel.SERVER_FLOPS)
sched = DynamicTierScheduler(profile, n_clients=3)

# --- synthetic clients with heterogeneous resources -------------------------
task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
profiles = [timemodel.ResourceProfile(4.0, 100.0),
            timemodel.ResourceProfile(1.0, 30.0),
            timemodel.ResourceProfile(0.1, 10.0)]

for rnd in range(3):
    assign = sched.schedule()
    updated, weights = [], []
    for k, tier in assign.items():
        # 1. client downloads its tier's client-side model
        client_p, server_p = R.split_params(params, cfg, tier + 1)
        aux_p = R.aux_init(jax.random.PRNGKey(k), cfg, tier + 1)
        labels = np.random.default_rng(k).integers(0, 10, 32)
        images = jnp.asarray(task.sample(labels, seed=rnd * 10 + k))
        labels = jnp.asarray(labels)

        # 2-3. client forward + local-loss update (aux head)
        def client_loss(cp, ap):
            z = R.client_forward(cp, cfg, images)
            logits = R.aux_apply(ap, z)
            one = jax.nn.one_hot(labels, 10)
            return -jnp.mean(jnp.sum(one * jax.nn.log_softmax(logits), -1)), z

        (closs, z), (cg, ag) = jax.value_and_grad(client_loss, (0, 1), has_aux=True)(
            client_p, aux_p)
        client_p, _ = opt.update(client_p, cg, opt.init(client_p))

        # 4. server updates the server-side model on detached z, in parallel
        z = jax.lax.stop_gradient(z)

        def server_loss(sp):
            logits = R.server_forward(sp, cfg, z, tier + 1)
            one = jax.nn.one_hot(labels, 10)
            return -jnp.mean(jnp.sum(one * jax.nn.log_softmax(logits), -1))

        sloss, sg = jax.value_and_grad(server_loss)(server_p)
        server_p, _ = opt.update(server_p, sg, opt.init(server_p))

        # 5. merge halves; report observed time to the scheduler
        updated.append(R.merge_params(client_p, server_p))
        weights.append(32)
        t = timemodel.simulate_client_times(costs, tier, profiles[k], 4, n_sharing=3)
        sched.observe(k, tier=tier, total_client_time=t["client"] + t["comm"],
                      nu=profiles[k].bytes_per_s, n_batches=4)
        print(f"round {rnd} client {k}: tier={tier + 1} closs={closs:.3f} "
              f"sloss={sloss:.3f} sim_time={t['total']:.1f}s")

    params = aggregation.weighted_average(updated, weights)
print("done — tiers adapt to the observed client speeds across rounds")
