"""Quickstart: one declarative spec -> a full DTFL run.

The whole experiment — model, data, heterogeneous environment, trainer,
engine, execution plane — is ONE frozen, JSON-round-trippable
``ExperimentSpec``; ``spec.build()`` wires everything and ``run()`` trains.
Tweak any field with ``with_overrides`` (every change is re-validated
against the component registries at spec time).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import presets

spec = presets.quickstart(rounds=3, clients=4)
print("spec:", spec.to_json(indent=1))
print("spec hash:", spec.spec_hash(), "\n")

logs = spec.build().run(verbose=True)
print(f"\ndtfl: {len(logs)} rounds, sim_clock={logs[-1].clock:,.0f}s "
      f"acc={logs[-1].acc:.3f}")

# any field is one override away — e.g. the FedAvg baseline on the same data
fedavg = spec.with_overrides({"trainer.method": "fedavg"})
logs2 = fedavg.build().run()
print(f"fedavg: sim_clock={logs2[-1].clock:,.0f}s acc={logs2[-1].acc:.3f}")
print(f"dtfl vs fedavg simulated speedup: "
      f"{logs2[-1].clock / max(logs[-1].clock, 1e-9):.1f}x "
      "(tiers adapt to the observed client speeds across rounds)")
