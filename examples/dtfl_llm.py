"""DTFL on an assigned transformer arch: split-offloaded federated LM
training (smollm-360m reduced) with the dynamic tier scheduler — the
``presets.llm`` scenario.

Demonstrates that the paper's technique is model-agnostic in this framework:
the same spec drives CNNs and every assigned architecture family (swap
``model.arch``, and the registry picks the adapter + token-LM data plane).

    PYTHONPATH=src python examples/dtfl_llm.py [--arch granite-3-2b]
"""
import argparse

from repro import presets, registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=[n for n in registry.archs.names()
                             if registry.archs.meta(n)["kind"] == "transformer"])
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    spec = presets.llm(args.arch, rounds=args.rounds, clients=args.clients,
                       seq_len=args.seq_len)
    logs = spec.build().run(verbose=True)
    print(f"[{args.arch}] next-token acc {logs[0].acc:.3f} -> {logs[-1].acc:.3f}; "
          f"sim clock {logs[-1].clock:,.0f}s "
          f"(times priced on the FULL {args.arch} cost table)")


if __name__ == "__main__":
    main()
