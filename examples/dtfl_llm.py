"""DTFL on an assigned transformer arch: split-offloaded federated LM
training (smollm-360m reduced) with the dynamic tier scheduler.

Demonstrates that the paper's technique is model-agnostic in this framework:
the same trainer drives CNNs and every assigned architecture family.

    PYTHONPATH=src python examples/dtfl_llm.py [--arch granite-3-2b]
"""
import argparse

from repro import optim
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data.synthetic import SeqTask
from repro.fed import DTFLTrainer, HeteroEnv, SimClient, TransformerAdapter
from repro.launch.train import SeqClientDataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ASSIGNED_ARCHS)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = full.reduced()
    adapter = TransformerAdapter(cfg, seq_len=args.seq_len, cost_cfg=full)
    task = SeqTask(vocab=adapter.cfg.vocab)
    clients = [SimClient(i, SeqClientDataset(task, 2, 8, args.seq_len, i), None)
               for i in range(args.clients)]
    ev = next(task.batches(16, args.seq_len, 1, seed=99))
    env = HeteroEnv(args.clients, switch_every=3, seed=0)
    tr = DTFLTrainer(adapter, clients, env, optim.adam(2e-3), seed=0)
    logs = tr.run(args.rounds, ev, verbose=True)
    print(f"[{args.arch}] next-token acc {logs[0].acc:.3f} -> {logs[-1].acc:.3f}; "
          f"sim clock {logs[-1].clock:,.0f}s "
          f"(times priced on the FULL {args.arch} cost table)")


if __name__ == "__main__":
    main()
