"""Spec-grid sweep plane: expand an ExperimentSpec grid, run every point,
emit one tidy CSV.

A sweep is ``base preset x cartesian grid of dotted-path overrides``::

  PYTHONPATH=src:. python benchmarks/sweep.py \
      --preset quickstart --rounds 2 \
      --grid "trainer.method=dtfl,fedavg data.clients=3,4" --out sweep.csv

Each grid point is ``base.with_overrides({...})`` — so every point is
re-validated by the spec layer, and an illegal combination fails BEFORE any
point runs. Points are executed grouped by ``spec.program_key()`` and each
``Federation`` is built with ``reuse=<previous point>``: grid points that
share (arch, batch shape, tier count, lr, codec, exec plane) transplant the
previous point's compiled per-tier cohort programs and jitted eval instead
of recompiling them. On this 2-CPU box recompilation dominates small
sweeps, so program reuse is the speed win — the ``programs_reused`` CSV
column records where it applied.

CSV schema (one header row, then one row per grid point, in run order):
  preset,point,<grid key 1>,...,<grid key K>,rounds_run,final_acc,
      sim_clock_s,wall_s,programs_reused

``--spec file.json`` sweeps around an explicit spec (e.g. one written by
``repro.launch.train --out-spec``) instead of a named preset.
"""
from __future__ import annotations

import argparse
import itertools
import time

from repro import presets
from repro.api import ExperimentSpec, Federation, SpecError


def parse_grid(grid: str) -> list[tuple[str, list[str]]]:
    """``"a.b=1,2 c.d=x,y"`` (space/semicolon separated) -> ordered axes."""
    axes = []
    for part in grid.replace(";", " ").split():
        if "=" not in part:
            raise SpecError(f"bad grid axis {part!r}; expected path=v1,v2,...")
        path, _, vals = part.partition("=")
        values = [v for v in vals.split(",") if v != ""]
        if not values:
            raise SpecError(f"grid axis {path!r} has no values")
        axes.append((path, values))
    return axes


def expand(base: ExperimentSpec, axes: list[tuple[str, list[str]]]
           ) -> list[tuple[dict, ExperimentSpec]]:
    """Cartesian product of the grid axes over ``base`` — every point is a
    fully validated spec (illegal combos fail here, before anything runs)."""
    points = []
    for combo in itertools.product(*(vals for _, vals in axes)):
        overrides = {path: v for (path, _), v in zip(axes, combo)}
        points.append((overrides, base.with_overrides(overrides)))
    return points


def main(emit_fn=print, *, preset: str = "quickstart",
         grid: str = "trainer.method=dtfl,fedavg data.clients=3,4",
         rounds: int | None = 2, base: ExperimentSpec | None = None,
         verbose: bool = False):
    if base is None:
        if preset not in presets.PRESETS:
            raise SpecError(f"unknown preset {preset!r}; registered presets: "
                            + ", ".join(sorted(presets.PRESETS)))
        base = presets.PRESETS[preset]()
    if rounds is not None:
        base = base.with_overrides({"rounds": rounds, "target_acc": None})
    axes = parse_grid(grid)
    points = expand(base, axes)
    # run grouped by program key so consecutive points can transplant the
    # previous Federation's compiled programs (the CSV stays in run order;
    # ``point`` is the grid index)
    order = sorted(range(len(points)),
                   key=lambda i: (repr(points[i][1].program_key()), i))

    rows = [("preset", "point", *(path for path, _ in axes), "rounds_run",
             "final_acc", "sim_clock_s", "wall_s", "programs_reused")]
    prev = None
    for i in order:
        overrides, spec = points[i]
        t0 = time.perf_counter()
        fed = Federation(spec, reuse=prev)
        logs = fed.run(verbose=verbose)
        wall = time.perf_counter() - t0
        rows.append((preset, i, *(overrides[p] for p, _ in axes), len(logs),
                     round(logs[-1].acc, 4), round(logs[-1].clock, 1),
                     round(wall, 2), fed.programs_reused))
        prev = fed
    for r in rows:
        emit_fn(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="quickstart",
                    help="base scenario: " + ", ".join(sorted(presets.PRESETS)))
    ap.add_argument("--spec", default=None,
                    help="sweep around an explicit spec JSON file instead of "
                         "a preset (e.g. from train.py --out-spec)")
    ap.add_argument("--grid", default="trainer.method=dtfl,fedavg data.clients=3,4",
                    help='space/;-separated axes: "path=v1,v2 path2=v3,v4"')
    ap.add_argument("--rounds", type=int, default=None,
                    help="override every point's round budget (clears "
                         "target_acc); default: the base spec's")
    ap.add_argument("--out", default=None, help="also write the CSV here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    base = None
    if args.spec:
        with open(args.spec) as f:
            base = ExperimentSpec.from_json(f.read())
    lines = []

    def tee(s):
        print(s)
        lines.append(s)

    # with --spec, "preset" is only the CSV label column — name the file
    main(tee, preset=args.spec if args.spec else args.preset, grid=args.grid,
         rounds=args.rounds, base=base, verbose=args.verbose)
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")
