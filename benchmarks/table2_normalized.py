"""Paper Table 2: normalized per-tier training times are client-independent.

For a pool of random CPU capacities, the per-tier client-side time normalized
by tier 1 must be the same for every client (std ~ 0) — the invariance the
dynamic scheduler's extrapolation relies on (Algorithm 1 lines 24-29).

CSV rows: ``table2,<tier>,<normalized_time_mean>,<normalized_time_std>``
"""
from __future__ import annotations

import numpy as np

from repro.configs.resnet_cifar import RESNET56
from repro.core import timemodel


def main(emit=print):
    costs = timemodel.resnet_tier_costs(RESNET56, batch_size=100)
    rng = np.random.default_rng(0)
    cpus = rng.uniform(0.1, 4.0, 10)
    norm = []
    for cpu in cpus:
        t = costs.client_flops / (cpu * timemodel.UNIT_FLOPS)
        norm.append(t / t[0])
    norm = np.array(norm)               # (clients, tiers)
    out = []
    for m in range(costs.n_tiers):
        out.append(("table2", m + 1, round(float(norm[:, m].mean()), 4),
                    round(float(norm[:, m].std()), 10)))
    for r in out:
        emit(",".join(str(x) for x in r))
    # the paper's Table-2 claim: ratios are client-independent
    assert float(np.abs(norm.std(axis=0)).max()) < 1e-9
    return out


if __name__ == "__main__":
    main()
