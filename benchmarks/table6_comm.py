"""Table 6 (repo extension): the compressed-uplink communication plane.

Bytes-per-round and simulated time-to-target vs wire codec, DTFL on the
paper's heterogeneous environment AND on its most bandwidth-starved profile
(0.1 CPU / 10 Mbps — Sec. 4.1's slowest class), as the ``presets.table6``
scenario. Compression round-trips run INSIDE the jitted cohort programs, so
accuracy dynamics are the real quantized/sparsified ones, and the time model
+ tier scheduler price the codec-true wire bytes (core/codec.py) — the
scheduler can therefore re-tier when compression shifts the
compute/communication balance.

Claims reproduced/extended:
  (a) identity reproduces the uncompressed path exactly (its row is the
      baseline the others are normalized against);
  (b) on the 10 Mbps profile, int8 reaches the accuracy target in
      measurably less *simulated* time than identity, because the comm
      share of Eq. 5 shrinks ~4x while convergence barely moves; top-k cuts
      bytes hardest, but at aggressive fractions (0.05) the sparsified z
      uplink slows convergence — the codec/accuracy trade-off this table
      exposes (its download wire rides dense: error feedback lives on the
      client and cannot repair a truncated broadcast);
  (c) per-round uplink bytes drop by the codec's wire ratio (reported from
      codec-true sizes, not analytic fp32 counts).

CSV rows:
  table6,<env>,<codec>,<exec>,<engine>,<rounds_run>,<final_acc>,
      <sim_time_s>,<uplink_bytes_per_round>
  table6_speedup,<env>,<codec>,<time_identity/time_codec>,
      <uplink_identity/uplink_codec>

``--exec``/``--engine`` sweep the execution planes (loop | cohort | sharded)
and engines (rounds | events) — all support every codec.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro import presets
from benchmarks.common import run_spec

CODECS = ("identity", "bf16", "int8", "topk0.05")


def main(emit_fn=print, *, rounds=10, target=0.55, n_clients=6, samples=1200,
         codecs=CODECS, exec_modes=("cohort",), engines=("rounds",),
         envs=("slow10mbps", "paper"), devices=None, seed=0):
    rows = []
    for env_name in envs:
        for exec_mode in exec_modes:
            for engine in engines:
                base_time = base_up = None
                for codec in codecs:
                    logs, _ = run_spec(presets.table6(
                        codec, env=env_name, exec_mode=exec_mode,
                        engine=engine, devices=devices, rounds=rounds,
                        target=target, clients=n_clients, samples=samples,
                        seed=seed))
                    sim_t = logs[-1].clock
                    up = float(np.mean([l.uplink_bytes for l in logs]))
                    rows.append(("table6", env_name, codec, exec_mode, engine,
                                 len(logs), round(logs[-1].acc, 4),
                                 round(sim_t, 1), round(up, 0)))
                    if codec == "identity":
                        base_time, base_up = sim_t, up
                    elif base_time is not None:
                        rows.append(("table6_speedup", env_name, codec,
                                     round(base_time / max(sim_t, 1e-9), 3),
                                     round(base_up / max(up, 1e-9), 3)))
    for r in rows:
        emit_fn(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--target", type=float, default=0.55)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--exec", dest="exec_modes", default="cohort",
                    help="comma list: loop,cohort,sharded")
    ap.add_argument("--engine", dest="engines", default="rounds",
                    help="comma list: rounds,events")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size for --exec sharded")
    args = ap.parse_args()
    if "sharded" in args.exec_modes and args.devices:
        from repro.launch.mesh import ensure_sim_devices

        ensure_sim_devices(args.devices)
    main(rounds=args.rounds, target=args.target, n_clients=args.clients,
         exec_modes=tuple(args.exec_modes.split(",")),
         engines=tuple(args.engines.split(",")), devices=args.devices)
