"""Paper Table 1: static-tier training time to a target accuracy.

Faithful protocol: the table's entries are TIME-TO-TARGET, so each static
tier pays (rounds-to-target at that tier) x (per-round straggler time under
the case's resource profiles). Rounds-to-target come from REAL training of a
width-reduced ResNet with a StaticScheduler per tier (low tiers converge
slower: tiny client models + local loss) — the ``presets.table1_static``
scenario; per-round times are priced on the full ResNet-110 cost table.

Claims reproduced: (a) time varies non-trivially across tiers and the best
static tier depends on the resource case; (b) FedAvg is no better than the
best static tier — the motivation for DYNAMIC tiering.

CSV rows (via benchmarks/common.py conventions):
  table1,<case>,<tier|fedavg>,<rounds>,<compute_s>,<comm_s>,<total_s>
  table1,<case>,best_tier,<tier>,beats_fedavg,<bool>,
"""
from __future__ import annotations

import functools

from repro import presets
from repro.configs.resnet_cifar import RESNET110
from repro.core import timemodel
from repro.core.timemodel import CASE1_PROFILES, CASE2_PROFILES
from benchmarks.common import run_spec

N_BATCHES = 10
TARGET = 0.75
MAX_ROUNDS = 30


@functools.lru_cache(maxsize=None)
def rounds_to_target(tier: int | None) -> int:
    """Real training with everyone in ``tier`` (None = FedAvg)."""
    logs, _ = run_spec(presets.table1_static(tier, rounds=MAX_ROUNDS,
                                             target=TARGET))
    return len(logs)


def per_round_time(costs, m, profiles, n_clients=10, n_sharing=10):
    tot = []
    for i in range(n_clients):
        prof = profiles[i % len(profiles)]
        t = timemodel.simulate_client_times(costs, m, prof, N_BATCHES,
                                            n_sharing=n_sharing)
        tot.append((max(t["client"], t["server"]), t["comm"], t["total"]))
    comp = max(t[0] for t in tot)
    comm = max(t[1] for t in tot)
    return comp, comm, max(t[2] for t in tot)


def main(emit_fn=print):
    costs = timemodel.resnet_tier_costs(RESNET110, batch_size=100)
    out = []
    for case, profiles in (("case1", CASE1_PROFILES), ("case2", CASE2_PROFILES)):
        totals = {}
        for m in range(costs.n_tiers):
            R = rounds_to_target(m)
            comp, comm, tot = per_round_time(costs, m, profiles)
            totals[m + 1] = R * tot
            out.append(("table1", case, m + 1, R, round(R * comp), round(R * comm),
                        round(R * tot)))
        R = rounds_to_target(None)
        prof_t = []
        for i in range(10):
            prof = profiles[i % len(profiles)]
            prof_t.append(costs.full_flops * N_BATCHES / prof.flops
                          + 2 * costs.full_param_bytes / prof.bytes_per_s)
        totals["fedavg"] = R * max(prof_t)
        out.append(("table1", case, "fedavg", R, round(R * max(prof_t)), 0,
                    round(R * max(prof_t))))
        best = min(((k, v) for k, v in totals.items() if k != "fedavg"),
                   key=lambda kv: kv[1])
        out.append(("table1", case, "best_tier", best[0],
                    "beats_fedavg", totals["fedavg"] >= best[1], ""))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
