"""Paper Table 5: privacy integration — distance correlation regularizer
(alpha sweep) and patch shuffling; accuracy after a fixed round budget, on
the ``presets.table5`` scenario (intermediate-difficulty noisy task, where
the regularizer's capacity cost is visible: paper 87.1 -> 75.6 over the
alpha sweep).

Claim reproduced: small alpha costs little accuracy; accuracy degrades as
alpha grows; patch shuffling has minimal impact.

CSV rows: ``table5,<dcor_<alpha>|patch_shuffle|alpha_trend_ok>,<acc|bool>``
"""
from __future__ import annotations

from repro import presets
from benchmarks.common import run_spec


def main(emit_fn=print, rounds=6):
    out = []
    accs = {}
    for alpha in (0.0, 0.25, 0.5, 0.75):
        logs, _ = run_spec(presets.table5(alpha, rounds=rounds))
        accs[alpha] = logs[-1].acc
        out.append(("table5", f"dcor_{alpha}", round(logs[-1].acc, 3)))
    logs, _ = run_spec(presets.table5(patch_shuffle=True, rounds=rounds))
    out.append(("table5", "patch_shuffle", round(logs[-1].acc, 3)))
    out.append(("table5", "alpha_trend_ok", accs[0.0] >= accs[0.75] - 0.02))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
