"""Paper Table 5: privacy integration — distance correlation regularizer
(alpha sweep) and patch shuffling; accuracy after a fixed round budget.

Claim reproduced: small alpha costs little accuracy; accuracy degrades as
alpha grows; patch shuffling has minimal impact.

CSV rows: ``table5,<dcor_<alpha>|patch_shuffle|alpha_trend_ok>,<acc|bool>``
"""
from __future__ import annotations

import jax

from repro import optim
from repro.configs.resnet_cifar import RESNET56
from repro.fed import DTFLTrainer, HeteroEnv, ResNetAdapter
from benchmarks.common import image_setup


def main(emit_fn=print, rounds=6):
    out = []
    # noise 1.0: an intermediate-difficulty task where the regularizer's
    # capacity cost is visible (paper: 87.1 -> 75.6 over the alpha sweep)
    import numpy as np
    from repro.data.partition import iid_partition
    from repro.data.pipeline import ClientDataset, make_eval_batch
    from repro.data.synthetic import ClassImageTask
    from repro.fed import SimClient
    from repro.configs.resnet_cifar import RESNET56 as _R56

    cfg = _R56.reduced()
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size, noise=1.0)
    labels = np.random.default_rng(0).integers(0, 10, 1200)
    parts = iid_partition(labels, 5, 0)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], 32), None)
               for i in range(5)]
    ev = make_eval_batch(task, 512)
    accs = {}
    for alpha in (0.0, 0.25, 0.5, 0.75):
        adapter = ResNetAdapter(cfg, cost_cfg=RESNET56, dcor_alpha=alpha)
        tr = DTFLTrainer(adapter, clients, HeteroEnv(5, seed=0), optim.adam(1e-3), seed=0)
        logs = tr.run(rounds, ev)
        accs[alpha] = logs[-1].acc
        out.append(("table5", f"dcor_{alpha}", round(logs[-1].acc, 3)))
    adapter = ResNetAdapter(cfg, cost_cfg=RESNET56, patch_shuffle=True)
    tr = DTFLTrainer(adapter, clients, HeteroEnv(5, seed=0), optim.adam(1e-3), seed=0)
    logs = tr.run(rounds, ev)
    out.append(("table5", "patch_shuffle", round(logs[-1].acc, 3)))
    out.append(("table5", "alpha_trend_ok", accs[0.0] >= accs[0.75] - 0.02))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
