# One module per paper table/figure. Each main() prints CSV rows
# ``table,<keys...>,<values...>``; this driver runs them all, or a subset:
#
#   python benchmarks/run.py --only table4_scaling,roofline
#
# It is also the wall-time regression gate: ``--check BENCH_table4.json``
# re-times only table4_scaling's wall rows (loop/cohort/sharded/chunked
# planes + the 100k-population regime) and exits non-zero if any is more
# than TOLERANCE x slower than the committed baseline;
# ``--write-baseline BENCH_table4.json`` refreshes the baseline from a
# fresh run on the current machine.
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time

# >1.5x slower than baseline fails the gate: wide enough to absorb shared-CI
# noise, tight enough to catch an accidentally re-introduced O(population)
# loop (those regress by integer factors, not percents)
TOLERANCE = 1.5

SUITES = [
    "table1_tier_times",
    "table2_normalized",
    "table3_baselines",
    "table4_scaling",
    "fig3_tier_count",
    "fig_async_timeline",
    "table5_privacy",
    "table6_comm",
    "sweep",
    "roofline",
]


def _fresh_walls() -> dict[str, float]:
    """Re-time table4_scaling's wall rows only (``sizes=()`` skips the
    accuracy sweeps), keyed ``<n>/<plane>`` and ``pop<P>/s<S>/c<C>``.

    Gate scope is reduced-but-representative so a CI run stays in minutes:
    the n=10 wall row per plane (an O(population) regression shows up at
    every n) plus the full 100k-registry/512-sample population regime. The
    3 warmup rounds let the scheduler's assignments — and with them the
    compiled cohort shapes — settle, so the single timed round is
    steady-state, not compile noise."""
    from benchmarks import table4_scaling

    walls: dict[str, float] = {}
    for row in table4_scaling.main(emit_fn=lambda _line: None, sizes=(),
                                   wall_sizes=(10,), wall_timed_rounds=1,
                                   wall_warmup_rounds=3, chunk_size=4):
        if row[0] == "table4_wall":
            walls[f"{row[1]}/{row[2]}"] = float(row[3])
        elif row[0] == "table4_population":
            walls[f"pop{row[1]}/s{row[2]}/c{row[3]}"] = float(row[4])
    return walls


def _check_baseline(path: str, out: str | None = None) -> int:
    with open(path) as f:
        base = json.load(f)
    tol = base.get("meta", {}).get("tolerance", TOLERANCE)
    fresh = _fresh_walls()
    if out:  # CI uploads the fresh measurement next to the verdict
        with open(out, "w") as f:
            json.dump({"meta": {"suite": "table4_scaling", "fresh": True},
                       "walls": fresh}, f, indent=1, sort_keys=True)
            f.write("\n")
    failures = 0
    for key, ref in sorted(base["walls"].items()):
        got = fresh.get(key)
        if got is None:
            # device-dependent rows (sharded_dN) legitimately vanish on
            # hosts with fewer visible devices — note, don't fail
            print(f"check: {key}: not measured on this host "
                  "(baseline {ref}s) — skipped", file=sys.stderr)
            continue
        verdict = "ok" if got <= tol * ref else "REGRESSION"
        print(f"check: {key}: {got:.3f}s vs baseline {ref:.3f}s "
              f"(limit {tol:.1f}x) {verdict}")
        failures += verdict != "ok"
    for key in sorted(set(fresh) - set(base["walls"])):
        print(f"check: {key}: new row ({fresh[key]:.3f}s), no baseline — "
              "refresh with --write-baseline", file=sys.stderr)
    return failures


def _write_baseline(path: str) -> None:
    walls = _fresh_walls()
    with open(path, "w") as f:
        json.dump({"meta": {"suite": "table4_scaling",
                            "tolerance": TOLERANCE},
                   "walls": walls}, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(walls)} wall baselines to {path}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite subset (e.g. "
                         "table4_scaling,roofline); default: all")
    ap.add_argument("--check", default=None, metavar="BENCH_table4.json",
                    help="regression gate: re-time the table4 wall rows and "
                         f"fail if any exceeds {TOLERANCE}x its baseline")
    ap.add_argument("--write-baseline", default=None,
                    metavar="BENCH_table4.json",
                    help="re-time the table4 wall rows and write them as "
                         "the new baseline")
    ap.add_argument("--out", default=None,
                    help="with --check: also write the fresh wall "
                         "measurements here (the CI artifact)")
    args = ap.parse_args(argv)
    if args.check and args.write_baseline:
        ap.error("--check and --write-baseline are exclusive")
    if args.check:
        sys.exit(1 if _check_baseline(args.check, out=args.out) else 0)
    if args.write_baseline:
        _write_baseline(args.write_baseline)
        return
    selected = SUITES
    if args.only:
        names = [s.strip() for s in args.only.split(",") if s.strip()]
        bad = [n for n in names if n not in SUITES]
        if bad:
            ap.error(f"unknown suite(s) {bad}; choose from {sorted(SUITES)}")
        selected = [s for s in SUITES if s in names]

    failures = 0
    for name in selected:
        t0 = time.time()
        print(f"### {name}")
        try:
            # import lazily so subset runs don't pay every suite's (jax-
            # heavy) import cost
            importlib.import_module(f"benchmarks.{name}").main()
            print(f"### {name} done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"### {name} FAILED: {e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
