# One module per paper table/figure. Each main() prints CSV rows
# ``table,<keys...>,<values...>``; this driver runs them all, or a subset:
#
#   python benchmarks/run.py --only table4_scaling,roofline
#
# It is also the regression gate. ``--check <BENCH_*.json>`` dispatches on
# the baseline's ``meta.suite``:
#   * table4_scaling — re-times the wall rows (loop/cohort/sharded/chunked
#     planes + the 100k-population regime) and exits non-zero if any is
#     more than TOLERANCE x slower than the committed baseline;
#   * table3_baselines — re-runs the dtfl vs dtfl_pairing clock comparison
#     and fails if either simulated clock regressed past tolerance or if
#     pairing stopped beating plain DTFL (the mutual-offload claim).
# ``--write-baseline <BENCH_*.json>`` refreshes a baseline from a fresh run
# on the current machine (suite inferred from the filename).
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time

# >1.5x slower than baseline fails the gate: wide enough to absorb shared-CI
# noise, tight enough to catch an accidentally re-introduced O(population)
# loop (those regress by integer factors, not percents)
TOLERANCE = 1.5

SUITES = [
    "table1_tier_times",
    "table2_normalized",
    "table3_baselines",
    "table4_scaling",
    "fig3_tier_count",
    "fig_async_timeline",
    "table5_privacy",
    "table6_comm",
    "sweep",
    "roofline",
]


def _fresh_walls() -> dict[str, float]:
    """Re-time table4_scaling's wall rows only (``sizes=()`` skips the
    accuracy sweeps), keyed ``<n>/<plane>`` and ``pop<P>/s<S>/c<C>``.

    Gate scope is reduced-but-representative so a CI run stays in minutes:
    the n=10 wall row per plane (an O(population) regression shows up at
    every n) plus the full 100k-registry/512-sample population regime. The
    3 warmup rounds let the scheduler's assignments — and with them the
    compiled cohort shapes — settle, so the single timed round is
    steady-state, not compile noise."""
    from benchmarks import table4_scaling

    walls: dict[str, float] = {}
    for row in table4_scaling.main(emit_fn=lambda _line: None, sizes=(),
                                   wall_sizes=(10,), wall_timed_rounds=1,
                                   wall_warmup_rounds=3, chunk_size=4):
        if row[0] == "table4_wall":
            walls[f"{row[1]}/{row[2]}"] = float(row[3])
        elif row[0] == "table4_population":
            walls[f"pop{row[1]}/s{row[2]}/c{row[3]}"] = float(row[4])
    return walls


def _fresh_table3(meta: dict) -> dict[str, float]:
    """Re-run the gate-scoped slice of table3_baselines: dtfl vs
    dtfl_pairing on the IID split only, keyed ``<iid|noniid>/<method>``.
    Clocks are SIMULATED time — deterministic given the seed — so the gate
    is cheap enough for CI yet pins the mutual-offload speedup claim."""
    from benchmarks import table3_baselines

    rows = table3_baselines.main(
        emit_fn=lambda _line: None,
        rounds=int(meta.get("rounds", 10)),
        target=float(meta.get("target", 0.55)),
        methods=tuple(meta.get("methods", ("dtfl", "dtfl_pairing"))),
        iids=(True,))
    return {f"{r[1]}/{r[2]}": float(r[3]) for r in rows
            if r[2] in ("dtfl", "dtfl_pairing")}


def _check_table3(base: dict, out: str | None = None) -> int:
    tol = base.get("meta", {}).get("tolerance", TOLERANCE)
    fresh = _fresh_table3(base.get("meta", {}))
    if out:
        with open(out, "w") as f:
            json.dump({"meta": {"suite": "table3_baselines", "fresh": True},
                       "clocks": fresh}, f, indent=1, sort_keys=True)
            f.write("\n")
    failures = 0
    for key, ref in sorted(base["clocks"].items()):
        got = fresh.get(key)
        if got is None:
            print(f"check: {key}: not measured — skipped", file=sys.stderr)
            continue
        verdict = "ok" if got <= tol * ref else "REGRESSION"
        print(f"check: {key}: clock {got:.0f}s vs baseline {ref:.0f}s "
              f"(limit {tol:.1f}x) {verdict}")
        failures += verdict != "ok"
    # the headline invariant: mutual offload must beat plain DTFL
    dt, pair = fresh.get("iid/dtfl"), fresh.get("iid/dtfl_pairing")
    if dt is not None and pair is not None:
        verdict = "ok" if pair < dt else "REGRESSION"
        print(f"check: iid/dtfl_pairing < iid/dtfl: {pair:.0f}s vs "
              f"{dt:.0f}s {verdict}")
        failures += verdict != "ok"
    return failures


def _check_baseline(path: str, out: str | None = None) -> int:
    with open(path) as f:
        base = json.load(f)
    if base.get("meta", {}).get("suite") == "table3_baselines":
        return _check_table3(base, out=out)
    tol = base.get("meta", {}).get("tolerance", TOLERANCE)
    fresh = _fresh_walls()
    if out:  # CI uploads the fresh measurement next to the verdict
        with open(out, "w") as f:
            json.dump({"meta": {"suite": "table4_scaling", "fresh": True},
                       "walls": fresh}, f, indent=1, sort_keys=True)
            f.write("\n")
    failures = 0
    for key, ref in sorted(base["walls"].items()):
        got = fresh.get(key)
        if got is None:
            # device-dependent rows (sharded_dN) legitimately vanish on
            # hosts with fewer visible devices — note, don't fail
            print(f"check: {key}: not measured on this host "
                  "(baseline {ref}s) — skipped", file=sys.stderr)
            continue
        verdict = "ok" if got <= tol * ref else "REGRESSION"
        print(f"check: {key}: {got:.3f}s vs baseline {ref:.3f}s "
              f"(limit {tol:.1f}x) {verdict}")
        failures += verdict != "ok"
    for key in sorted(set(fresh) - set(base["walls"])):
        print(f"check: {key}: new row ({fresh[key]:.3f}s), no baseline — "
              "refresh with --write-baseline", file=sys.stderr)
    return failures


def _write_baseline(path: str) -> None:
    if "table3" in path.rsplit("/", 1)[-1]:
        meta = {"suite": "table3_baselines", "tolerance": TOLERANCE,
                "rounds": 10, "target": 0.55,
                "methods": ["dtfl", "dtfl_pairing"]}
        clocks = _fresh_table3(meta)
        with open(path, "w") as f:
            json.dump({"meta": meta, "clocks": clocks}, f, indent=1,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote {len(clocks)} clock baselines to {path}")
        return
    walls = _fresh_walls()
    with open(path, "w") as f:
        json.dump({"meta": {"suite": "table4_scaling",
                            "tolerance": TOLERANCE},
                   "walls": walls}, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(walls)} wall baselines to {path}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite subset (e.g. "
                         "table4_scaling,roofline); default: all")
    ap.add_argument("--check", default=None, metavar="BENCH_*.json",
                    help="regression gate: re-measure the baseline's suite "
                         "(meta.suite: table4_scaling walls or "
                         "table3_baselines clocks) and fail if any row "
                         f"exceeds {TOLERANCE}x its baseline (table3 also "
                         "fails if dtfl_pairing stops beating dtfl)")
    ap.add_argument("--write-baseline", default=None,
                    metavar="BENCH_*.json",
                    help="re-measure and write a new baseline (suite "
                         "inferred from the filename: table3 vs table4)")
    ap.add_argument("--out", default=None,
                    help="with --check: also write the fresh wall "
                         "measurements here (the CI artifact)")
    args = ap.parse_args(argv)
    if args.check and args.write_baseline:
        ap.error("--check and --write-baseline are exclusive")
    if args.check:
        sys.exit(1 if _check_baseline(args.check, out=args.out) else 0)
    if args.write_baseline:
        _write_baseline(args.write_baseline)
        return
    selected = SUITES
    if args.only:
        names = [s.strip() for s in args.only.split(",") if s.strip()]
        bad = [n for n in names if n not in SUITES]
        if bad:
            ap.error(f"unknown suite(s) {bad}; choose from {sorted(SUITES)}")
        selected = [s for s in SUITES if s in names]

    failures = 0
    for name in selected:
        t0 = time.time()
        print(f"### {name}")
        try:
            # import lazily so subset runs don't pay every suite's (jax-
            # heavy) import cost
            importlib.import_module(f"benchmarks.{name}").main()
            print(f"### {name} done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"### {name} FAILED: {e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
