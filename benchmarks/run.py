# One module per paper table/figure. Each main() prints CSV rows
# ``table,<keys...>,<values...>``; this driver runs them all.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig3_tier_count, fig_async_timeline, roofline,
                            table1_tier_times, table2_normalized,
                            table3_baselines, table4_scaling, table5_privacy)

    suites = [
        ("table1_tier_times", table1_tier_times.main),
        ("table2_normalized", table2_normalized.main),
        ("table3_baselines", table3_baselines.main),
        ("table4_scaling", table4_scaling.main),
        ("fig3_tier_count", fig3_tier_count.main),
        ("fig_async_timeline", fig_async_timeline.main),
        ("table5_privacy", table5_privacy.main),
        ("roofline", roofline.main),
    ]
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        print(f"### {name}")
        try:
            fn()
            print(f"### {name} done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"### {name} FAILED: {e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
