# One module per paper table/figure. Each main() prints CSV rows
# ``table,<keys...>,<values...>``; this driver runs them all, or a subset:
#
#   python benchmarks/run.py --only table4_scaling,roofline
from __future__ import annotations

import argparse
import importlib
import sys
import time

SUITES = [
    "table1_tier_times",
    "table2_normalized",
    "table3_baselines",
    "table4_scaling",
    "fig3_tier_count",
    "fig_async_timeline",
    "table5_privacy",
    "table6_comm",
    "sweep",
    "roofline",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite subset (e.g. "
                         "table4_scaling,roofline); default: all")
    args = ap.parse_args(argv)
    selected = SUITES
    if args.only:
        names = [s.strip() for s in args.only.split(",") if s.strip()]
        bad = [n for n in names if n not in SUITES]
        if bad:
            ap.error(f"unknown suite(s) {bad}; choose from {sorted(SUITES)}")
        selected = [s for s in SUITES if s in names]

    failures = 0
    for name in selected:
        t0 = time.time()
        print(f"### {name}")
        try:
            # import lazily so subset runs don't pay every suite's (jax-
            # heavy) import cost
            importlib.import_module(f"benchmarks.{name}").main()
            print(f"### {name} done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"### {name} FAILED: {e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
