"""Async-tier timeline: sync DTFL vs async DTFL vs FedAT time-to-accuracy.

The event engine's headline scenario: under the paper's 5-profile
heterogeneity WITH client churn (mid-round dropouts + profile switches),
synchronous DTFL pays every round for the slowest participant's best-tier
time, while async tiers (FedAT-style per-group pacing + staleness-weighted
merges, ``fed/engine.py: run_async``) let fast groups keep updating the
global model while slow groups are still in flight. Each mode is the
``presets.fig_async`` scenario (same seeded churn stream and rates per mode;
the REALIZED dropout/switch sequence still differs per mode because sync
draws per round while async draws per group wave). The figure data is the
full (virtual clock, accuracy) timeline of each mode plus the
time-to-target summary.

Modes:
  sync_dtfl   — DTFL through the event engine in sync mode (churn-aware)
  async_dtfl  — DTFL tiers aggregated asynchronously per speed group
  fedat       — full-model FedAT baseline (async, staleness-weighted)

CSV rows:
  fig_async_timeline,<mode>,<step>,<sim_clock_s>,<acc>
  fig_async,<mode>,time_to_target,<sim_clock_s>,<reached|budget>
  fig_async,speedup_async_vs_sync,<x>,,
"""
from __future__ import annotations

from repro import presets
from benchmarks.common import run_spec


def _time_to_target(logs, target):
    for l in logs:
        if l.acc >= target:
            return l.clock, "reached"
    return logs[-1].clock, "budget"


def main(emit_fn=print, rounds=12, target=0.55, n_clients=10, n_groups=3,
         churn=True, seed=0):
    out = []
    summary = {}
    for mode in ("sync_dtfl", "async_dtfl", "fedat"):
        logs, _ = run_spec(presets.fig_async(
            mode, rounds=rounds, target=target, clients=n_clients,
            n_groups=n_groups, churn=churn, seed=seed))
        for l in logs:
            out.append(("fig_async_timeline", mode, l.round,
                        round(l.clock), round(l.acc, 3)))
        clock, status = _time_to_target(logs, target)
        summary[mode] = clock
        out.append(("fig_async", mode, "time_to_target", round(clock), status))
    out.append(("fig_async", "speedup_async_vs_sync",
                round(summary["sync_dtfl"] / max(summary["async_dtfl"], 1e-9), 2),
                "", ""))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
