"""Roofline table assembly from the dry-run artifacts (experiments/dryrun/).

Prints the per-(arch x shape) three-term roofline, the dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs usefulness ratio, and a one-line lever suggestion.
Populated by ``python -m repro.launch.dryrun --all``.

CSV rows: ``roofline,<arch>,<shape>,<mesh>,<preset>,<compute_ms>,
<memory_ms>,<collective_ms>,<dominant>,<useful_flops_ratio>,<temp_gib>``
(or ``roofline,NO_DATA,...`` when no dry-run artifacts exist).
"""
from __future__ import annotations

import glob
import json
import os

LEVERS = {
    "compute": "raise arithmetic intensity: fuse aux+task heads, larger per-device batch",
    "memory": "cut HBM traffic: bf16 weight streaming, larger FSDP shard group, no kv repeat",
    "collective": "cut bytes on ICI: reduce FSDP all-gather (8-way group), overlap with compute",
}


def load_records(path="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        stem = os.path.basename(f)[:-5]
        for tag in ("seqpar", "serve_seq", "serve_dp", "megatron_sp"):
            if f"_{tag}" in stem:
                r["preset"] = tag
        if "_pv" in stem:
            r["preset"] = r.get("preset", "") + "+padvocab"
        r.setdefault("preset", "baseline")
        recs.append(r)
    return recs


def main(emit_fn=print, path="experiments/dryrun"):
    recs = load_records(path)
    if not recs:
        emit_fn("roofline,NO_DATA,run `python -m repro.launch.dryrun --all` first")
        return []
    out = []
    for r in recs:
        t = r["roofline"]
        out.append((
            "roofline", r["arch"], r["shape"], r["mesh"], r["preset"],
            f'{t["compute_s"]*1e3:.2f}ms', f'{t["memory_s"]*1e3:.2f}ms',
            f'{t["collective_s"]*1e3:.2f}ms', t["dominant"],
            f'{r["useful_flops_ratio"]:.2f}',
            f'{r["memory"]["temp_bytes"]/2**30:.1f}GiB',
        ))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
