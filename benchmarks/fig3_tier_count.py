"""Paper Figure 3: more tiers -> lower total training time (more scheduling
freedom), for both profile cases, profiles switching every 20 rounds.

CSV rows: ``fig3,<case>,<n_tiers>,<total_time_s>`` and
``fig3,<case>,7_vs_1_speedup,<x>``
"""
from __future__ import annotations

import numpy as np

from repro.configs.resnet_cifar import RESNET110
from repro.core import timemodel
from repro.core.scheduler import DynamicTierScheduler, TierProfile
from repro.core.timemodel import CASE1_PROFILES, CASE2_PROFILES

ROUNDS = 60
N_BATCHES = 10


def simulated_total_time(n_tiers: int, profiles, n_clients=10, seed=0) -> float:
    """Pure scheduler+timemodel simulation (no gradient work): total straggler
    time over ROUNDS with profile switching every 20 rounds.

    Table-11 semantics: an M-tier deployment exposes the LAST M splits of the
    7-tier ResNet-110 design (M=1 -> everyone keeps md1..md7; larger M adds
    offloading options for slow clients)."""
    costs = timemodel.resnet_tier_costs(RESNET110, batch_size=100)
    prof = TierProfile.from_cost_table(costs, ref_flops=timemodel.UNIT_FLOPS,
                                       server_flops=timemodel.SERVER_FLOPS)
    allowed = list(range(costs.n_tiers))[-n_tiers:]
    sched = DynamicTierScheduler(prof, n_clients, allowed=allowed)
    rng = np.random.default_rng(seed)
    assign_prof = [profiles[i % len(profiles)] for i in range(n_clients)]
    total = 0.0
    for r in range(ROUNDS):
        if r and r % 20 == 0:
            for i in rng.choice(n_clients, n_clients // 3, replace=False):
                assign_prof[i] = profiles[rng.integers(len(profiles))]
        assign = sched.schedule()
        times = []
        for k, m in assign.items():
            t = timemodel.simulate_client_times(costs, m, assign_prof[k], N_BATCHES,
                                                n_sharing=n_clients)
            times.append(t["total"])
            sched.observe(k, tier=m, total_client_time=t["client"] + t["comm"],
                          nu=assign_prof[k].bytes_per_s, n_batches=N_BATCHES)
        total += max(times)
    return total


def main(emit_fn=print):
    out = []
    for case, profiles in (("case1", CASE1_PROFILES), ("case2", CASE2_PROFILES)):
        times = {}
        for m in (1, 2, 3, 5, 7):
            times[m] = simulated_total_time(m, profiles)
            out.append(("fig3", case, m, round(times[m])))
        # claim: more tiers helps (7-tier beats 1-tier comfortably)
        out.append(("fig3", case, "7_vs_1_speedup", round(times[1] / times[7], 2)))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
