"""Shared benchmark scaffolding, rebuilt on the declarative experiment API.

Every benchmark module expresses its protocol as named ``repro.presets``
specs and runs them through :func:`run_spec` — the same
``ExperimentSpec.build().run()`` path as ``launch/train.py`` — so the
benchmarks cannot drift from the CLI wiring. Gradient dynamics run on
REDUCED models (CPU container); all reported times come from the analytic
time model priced on the FULL ResNet-56/110 (or full transformer) cost
tables via each spec's ``model.cost_model`` — the paper's own experiments
simulate resource profiles the same way.

Output convention: every benchmark module's ``main(emit_fn)`` prints CSV
rows ``<table>,<keys...>,<values...>`` (one schema per module, documented in
its docstring) so ``benchmarks/run.py`` output is machine-parseable as-is.
"""
from __future__ import annotations

from repro.api import ExperimentSpec, Federation


def run_spec(spec: ExperimentSpec, *, reuse: Federation | None = None,
             verbose: bool = False):
    """Build + run one spec; returns ``(logs, federation)``. Pass the
    previous point's federation as ``reuse`` to share its compiled cohort
    programs when the specs' ``program_key()`` match (benchmarks/sweep.py's
    recompilation lever)."""
    fed = Federation(spec, reuse=reuse)
    return fed.run(verbose=verbose), fed
