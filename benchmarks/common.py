"""Shared benchmark scaffolding.

Gradient dynamics run on REDUCED models (CPU container); all reported times
come from the analytic time model priced on the FULL ResNet-56/110 (or full
transformer) cost tables — the paper's own experiments simulate resource
profiles the same way (DESIGN.md §2/§8).

Output convention: every benchmark module's ``main(emit_fn)`` prints CSV
rows ``<table>,<keys...>,<values...>`` (one schema per module, documented in
its docstring) so ``benchmarks/run.py`` output is machine-parseable as-is.
``run_method`` routes DTFL and the full-model baselines through the cohort
engine by default (``exec_plan="loop"`` selects the sequential debug path,
``ExecPlan.sharded(...)`` the mesh-sharded plane); FedGKT always runs its
sequential two-phase KD protocol.
"""
from __future__ import annotations

import time

import numpy as np

from repro import optim
from repro.configs.resnet_cifar import RESNET56, RESNET110, get_resnet
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.pipeline import ClientDataset, make_eval_batch
from repro.data.synthetic import ClassImageTask
from repro.fed import HeteroEnv, ResNetAdapter, SimClient, TRAINERS


def image_setup(n_clients=10, samples=2000, batch=32, iid=True, n_classes=10, seed=0):
    cfg = RESNET56.reduced()
    task = ClassImageTask(n_classes=n_classes, image_size=cfg.image_size)
    labels = np.random.default_rng(seed).integers(0, n_classes, samples)
    part = iid_partition(labels, n_clients, seed) if iid else dirichlet_partition(
        labels, n_clients, 0.5, seed)
    clients = [SimClient(i, ClientDataset(task, labels, part[i], batch), None)
               for i in range(n_clients)]
    return cfg, clients, make_eval_batch(task, 512)


def run_method(method, cfg, clients, ev, *, cost_model="resnet-110", rounds=8,
               target=None, scheduler="dynamic", participation=1.0, seed=0,
               switch_every=50, dcor_alpha=0.0, lr=1e-3, exec_plan=None,
               engine="rounds", churn=None, n_groups=3, codec=None,
               profiles=None):
    """``engine``: "rounds" (legacy scalar clock), "events" (discrete-event
    sync; supports ``churn``), or "async" (FedAT-style per-tier pacing).
    ``fedat`` always runs async regardless of ``engine``. ``exec_plan``:
    None/"cohort" | "loop" | ExecPlan.sharded(mesh) — the execution plane.
    ``codec``: communication codec spec (identity | bf16 | int8 | topk<f>).
    ``profiles``: resource-profile pool override for the HeteroEnv."""
    cost_cfg = get_resnet(cost_model)
    adapter = ResNetAdapter(cfg, cost_cfg=cost_cfg, dcor_alpha=dcor_alpha)
    env = HeteroEnv(len(clients), profiles=profiles,
                    switch_every=switch_every, seed=seed)
    kw = {"scheduler": scheduler} if method == "dtfl" else {}
    kw["exec_plan"] = exec_plan
    kw["codec"] = codec
    if method == "fedat":
        kw["n_groups"] = n_groups
    tr = TRAINERS[method](adapter, clients, env, optim.adam(lr), seed=seed, **kw)
    run_kw = {"churn": churn}
    if method != "fedat":  # FedAT is async by construction
        run_kw["engine"] = engine
    if engine == "async" and method != "fedat":
        run_kw["n_groups"] = n_groups
    logs = tr.run(rounds, ev, target_acc=target, participation=participation, **run_kw)
    return logs


def emit(rows: list[tuple]):
    for r in rows:
        print(",".join(str(x) for x in r))
