"""Paper Table 3: time-to-target-accuracy, DTFL vs FedAvg/SplitFed/FedYogi/
FedGKT/FedAT, IID and non-IID — the ``presets.table3`` scenario per method.

Gradient dynamics on the reduced ResNet; simulated clocks priced on the FULL
ResNet-110 cost table (paper's main config). Claim reproduced: DTFL reaches
the target in far less simulated time than every baseline. DTFL and the
full-model baselines (FedAvg/FedYogi/SplitFed/TiFL/drop30) run on the shared
cohort engine, so the comparison stays apples-to-apples at scale; FedGKT
keeps its sequential two-phase KD protocol (per-batch teacher state); FedAT
runs asynchronously on the event engine (per-tier pacing, staleness-weighted
merges) with its clock read from the virtual event clock.

``dtfl_pairing`` is DTFL under the mutual-offload topology (PairingScheduler
+ ``topology=pairing``): fast clients host slow clients' far halves, so the
server's capacity is shared over fewer participants and slow clients' far
halves run at peer speed. Same data, model, and heterogeneity profile —
only scheduling and time accounting differ.

CSV rows:
  table3,<iid|noniid>,<method>,<sim_clock_s>,<rounds>,<acc>,<reached|budget>
  table3,<iid|noniid>,dtfl_vs_fedavg_speedup,<x>,,,
  table3,<iid|noniid>,dtfl_pairing_vs_dtfl_speedup,<x>,,,
"""
from __future__ import annotations

from repro import presets
from benchmarks.common import run_spec

METHODS = ("dtfl", "dtfl_pairing", "fedavg", "fedyogi", "splitfed", "fedgkt",
           "fedat")


def _spec(method, *, iid, rounds, target):
    if method == "dtfl_pairing":
        return presets.table3("dtfl", iid=iid, rounds=rounds, target=target,
                              topology="pairing")
    return presets.table3(method, iid=iid, rounds=rounds, target=target)


def main(emit_fn=print, rounds=10, target=0.55, methods=METHODS,
         iids=(True, False)):
    out = []
    for iid in iids:
        for method in methods:
            logs, _ = run_spec(_spec(method, iid=iid, rounds=rounds,
                                     target=target))
            reached = logs[-1].acc >= target
            out.append((
                "table3", "iid" if iid else "noniid", method,
                round(logs[-1].clock), len(logs), round(logs[-1].acc, 3),
                "reached" if reached else "budget",
            ))
    clocks = {(r[1], r[2]): r[3] for r in out}
    for num, den, row in (("fedavg", "dtfl", "dtfl_vs_fedavg_speedup"),
                          ("dtfl", "dtfl_pairing",
                           "dtfl_pairing_vs_dtfl_speedup")):
        for iid in iids:
            k = "iid" if iid else "noniid"
            if (k, num) in clocks and (k, den) in clocks:
                out.append(("table3", k, row,
                            round(clocks[k, num] / max(clocks[k, den], 1), 2),
                            "", "", ""))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
