"""Paper Table 3: time-to-target-accuracy, DTFL vs FedAvg/SplitFed/FedYogi/
FedGKT/FedAT, IID and non-IID — the ``presets.table3`` scenario per method.

Gradient dynamics on the reduced ResNet; simulated clocks priced on the FULL
ResNet-110 cost table (paper's main config). Claim reproduced: DTFL reaches
the target in far less simulated time than every baseline. DTFL and the
full-model baselines (FedAvg/FedYogi/SplitFed/TiFL/drop30) run on the shared
cohort engine, so the comparison stays apples-to-apples at scale; FedGKT
keeps its sequential two-phase KD protocol (per-batch teacher state); FedAT
runs asynchronously on the event engine (per-tier pacing, staleness-weighted
merges) with its clock read from the virtual event clock.

CSV rows:
  table3,<iid|noniid>,<method>,<sim_clock_s>,<rounds>,<acc>,<reached|budget>
  table3,<iid|noniid>,dtfl_vs_fedavg_speedup,<x>,,,
"""
from __future__ import annotations

from repro import presets
from benchmarks.common import run_spec

METHODS = ("dtfl", "fedavg", "fedyogi", "splitfed", "fedgkt", "fedat")


def main(emit_fn=print, rounds=10, target=0.55):
    out = []
    for iid in (True, False):
        for method in METHODS:
            logs, _ = run_spec(presets.table3(method, iid=iid, rounds=rounds,
                                              target=target))
            reached = logs[-1].acc >= target
            out.append((
                "table3", "iid" if iid else "noniid", method,
                round(logs[-1].clock), len(logs), round(logs[-1].acc, 3),
                "reached" if reached else "budget",
            ))
    dt = {r[1]: r[3] for r in out if r[2] == "dtfl"}
    fa = {r[1]: r[3] for r in out if r[2] == "fedavg"}
    for k in dt:
        out.append(("table3", k, "dtfl_vs_fedavg_speedup", round(fa[k] / max(dt[k], 1), 2), "", "", ""))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
