"""Paper Table 4: scaling the client count (10% participation per round).

Claim reproduced: increasing the pool does not hurt DTFL; its simulated
time-to-target stays far below FedAvg at every scale.
"""
from __future__ import annotations

from benchmarks.common import image_setup, run_method


def main(emit_fn=print, rounds=8, target=0.5):
    out = []
    for n in (10, 20, 50):
        cfg, clients, ev = image_setup(n_clients=n, samples=200 * n)
        part = max(0.1, 2.0 / n)
        for method in ("dtfl", "fedavg"):
            logs = run_method(method, cfg, clients, ev, rounds=rounds,
                              target=target, participation=part)
            out.append(("table4", n, method, round(logs[-1].clock),
                        round(logs[-1].acc, 3)))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


if __name__ == "__main__":
    main()
