"""Paper Table 4: scaling the client count, plus the cohort-engine sweep.

Reproduces two claims:

* (paper, Table 4) increasing the pool does not hurt DTFL; its simulated
  time-to-target stays far below FedAvg at every scale.
  CSV rows: ``table4,<n_clients>,<method>,<sim_clock_s>,<acc>``
* (engine) the tier-cohort vectorized round engine (fed/cohort.py) beats the
  per-client sequential loop on real round wall-time, >=5x at 100+ clients
  on CPU — O(n_tiers) device programs per round instead of
  O(n_clients x n_batches) dispatches.
  CSV rows: ``table4_wall,<n_clients>,<engine>,<round_wall_s>`` followed by
  ``table4_speedup,<n_clients>,<x_speedup>``

Run directly (``python benchmarks/table4_scaling.py [--full]``) for the
10->500-client sweep; ``--full`` adds the largest sizes.
"""
from __future__ import annotations

import time

from benchmarks.common import image_setup, run_method


def main(emit_fn=print, rounds=8, target=0.5, sizes=(10, 20, 50),
         wall_sizes=(10, 50, 100), wall_timed_rounds=2, wall_warmup_rounds=3):
    out = []
    # ---- paper claim: simulated time-to-target vs pool size ---------------
    for n in sizes:
        cfg, clients, ev = image_setup(n_clients=n, samples=200 * n)
        part = max(0.1, 2.0 / n)
        for method in ("dtfl", "fedavg"):
            logs = run_method(method, cfg, clients, ev, rounds=rounds,
                              target=target, participation=part)
            out.append(("table4", n, method, round(logs[-1].clock),
                        round(logs[-1].acc, 3)))
    # ---- engine claim: round wall-time, sequential loop vs cohort engine --
    for n in wall_sizes:
        walls = {}
        for engine in ("loop", "cohort"):
            walls[engine] = _round_walltime(
                n, cohort=(engine == "cohort"),
                timed_rounds=wall_timed_rounds, warmup_rounds=wall_warmup_rounds,
            )
            out.append(("table4_wall", n, engine, round(walls[engine], 3)))
        out.append(("table4_speedup", n, round(walls["loop"] / walls["cohort"], 1)))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


def _round_walltime(n_clients: int, *, cohort: bool, timed_rounds: int,
                    warmup_rounds: int, samples_per_client: int = 64,
                    batch: int = 8) -> float:
    """Steady-state wall-time of one full-participation DTFL round.

    Measures ENGINE overhead scaling — many small clients, small per-step
    model (width-4 / 8px ResNet) — the regime the sequential loop's
    O(clients x batches) eager dispatches dominate; gradient quality is
    irrelevant here (table4's accuracy rows cover that). Warmup rounds
    absorb jit compilation and let the dynamic scheduler's assignments
    settle (observations are deterministic, so assignments — and with them
    the cohort shapes — stabilize after a few rounds)."""
    import dataclasses

    import numpy as np

    from repro import optim
    from repro.configs.resnet_cifar import RESNET56
    from repro.data.partition import iid_partition
    from repro.data.pipeline import ClientDataset
    from repro.data.synthetic import ClassImageTask
    from repro.fed import DTFLTrainer, HeteroEnv, ResNetAdapter, SimClient

    cfg = dataclasses.replace(RESNET56.reduced(), width=4, image_size=8)
    task = ClassImageTask(n_classes=10, image_size=cfg.image_size)
    labels = np.random.default_rng(0).integers(
        0, 10, samples_per_client * n_clients)
    parts = iid_partition(labels, n_clients, 0)
    clients = [SimClient(i, ClientDataset(task, labels, parts[i], batch), None)
               for i in range(n_clients)]
    adapter = ResNetAdapter(cfg, cost_cfg=None)
    env = HeteroEnv(n_clients, switch_every=0, seed=0)
    tr = DTFLTrainer(adapter, clients, env, optim.adam(1e-3), seed=0,
                     cohort=cohort)
    participants = list(range(n_clients))
    for r in range(warmup_rounds):
        tr.train_round(r, participants)
    t0 = time.perf_counter()
    for r in range(warmup_rounds, warmup_rounds + timed_rounds):
        tr.train_round(r, participants)
    return (time.perf_counter() - t0) / timed_rounds


if __name__ == "__main__":
    import sys

    full = "--full" in sys.argv
    main(sizes=(10, 20, 50), wall_sizes=(10, 50, 100, 200, 500) if full
         else (10, 50, 100))
