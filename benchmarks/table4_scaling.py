"""Paper Table 4: scaling the client count, plus the engine/plane sweeps.

Reproduces three claims:

* (paper, Table 4) increasing the pool does not hurt DTFL; its simulated
  time-to-target stays far below FedAvg at every scale
  (``presets.table4_accuracy``).
  CSV rows: ``table4,<n_clients>,<method>,<sim_clock_s>,<acc>``
* (engine) the tier-cohort vectorized round engine (fed/cohort.py) beats the
  per-client sequential loop on real round wall-time (~3.5x at 100 clients
  on this 2-core container under honest block-until-ready timing; grows
  with n) — O(n_tiers) device programs per round instead of
  O(n_clients x n_batches) dispatches (``presets.table4_wall``).
  CSV rows: ``table4_wall,<n_clients>,<exec>,<round_wall_s>`` followed by
  ``table4_speedup,<n_clients>,<x_speedup>``
* (sharded plane) sharding each cohort's client axis over a device mesh
  (fed/execplan.py) cuts round wall-time as the device count grows —
  the ``--xla_force_host_platform_device_count`` sim devices stand in for
  real accelerators; gains saturate at the PHYSICAL core count (a 2-core
  host shows d1 > d2 ≈ d4).
  CSV rows: ``table4_wall,<n_clients>,sharded_d<d>,<round_wall_s>`` and
  ``table4_shard_speedup,<n_clients>,<d>,<x_vs_single_device_cohort>``
  (emitted only for device counts actually visible to jax).
* (population plane) a 100k-client lazy registry with a fixed 512-client
  sample per round trains under ``exec=chunked`` with per-round wall-time
  and materialized state independent of the registry size — the chunked
  plane also joins the wall sweep above as ``table4_wall,<n>,chunked,...``.
  CSV rows: ``table4_population,<population>,<sample>,<chunk>,
  <round_wall_s>,<clients_touched>`` plus an informational
  ``table4_population_mem,<population>,<peak_rss_mb>`` row.

Run directly (``python benchmarks/table4_scaling.py [--full] [--devices N]``)
for the 10->500-client sweep; ``--devices N`` forces N simulated host
devices (must be set at launch, before jax initializes).

``benchmarks/run.py --check BENCH_table4.json`` replays only the wall-time
rows of this module and fails on a >1.5x regression against the committed
baseline (``--write-baseline`` refreshes it).
"""
from __future__ import annotations

import sys
import time


def main(emit_fn=print, rounds=8, target=0.5, sizes=(10, 20, 50),
         wall_sizes=(10, 50, 100), wall_timed_rounds=2, wall_warmup_rounds=3,
         shard_devices=(2, 4), chunk_size=16,
         population_regimes=((100_000, 512, 64),)):
    import jax

    from repro import presets
    from benchmarks.common import run_spec

    out = []
    # ---- paper claim: simulated time-to-target vs pool size ---------------
    for n in sizes:
        for method in ("dtfl", "fedavg"):
            logs, _ = run_spec(presets.table4_accuracy(
                n, method, rounds=rounds, target=target))
            out.append(("table4", n, method, round(logs[-1].clock),
                        round(logs[-1].acc, 3)))
    # ---- engine claim: round wall-time, loop vs cohort vs sharded ---------
    avail = len(jax.devices())
    usable = [d for d in shard_devices if d <= avail]
    dropped = [d for d in shard_devices if d > avail]
    if dropped:
        # stderr: stdout is the machine-parseable CSV stream
        print(f"table4: skipping sharded d={dropped} (only {avail} device(s) "
              "visible; set XLA_FLAGS=--xla_force_host_platform_device_count)",
              file=sys.stderr)
    for n in wall_sizes:
        walls = {}
        for mode in ("loop", "cohort", "chunked"):
            walls[mode] = _round_walltime(
                n, exec_mode=mode,
                chunk_size=chunk_size if mode == "chunked" else None,
                timed_rounds=wall_timed_rounds, warmup_rounds=wall_warmup_rounds,
            )
            out.append(("table4_wall", n, mode, round(walls[mode], 3)))
        out.append(("table4_speedup", n, round(walls["loop"] / walls["cohort"], 1)))
        for d in usable:
            t = _round_walltime(
                n, exec_mode="sharded", devices=d,
                timed_rounds=wall_timed_rounds, warmup_rounds=wall_warmup_rounds,
            )
            out.append(("table4_wall", n, f"sharded_d{d}", round(t, 3)))
            out.append(("table4_shard_speedup", n, d,
                        round(walls["cohort"] / t, 2)))
    # ---- population claim: O(sample) work/state from a 100k registry ------
    for pop, sample, chunk in population_regimes:
        out.extend(_population_rows(
            pop, sample, chunk,
            timed_rounds=wall_timed_rounds, warmup_rounds=1,
        ))
    for r in out:
        emit_fn(",".join(str(x) for x in r))
    return out


def _round_walltime(n_clients: int, *, exec_mode: str, devices: int | None = None,
                    chunk_size: int | None = None,
                    timed_rounds: int, warmup_rounds: int) -> float:
    """Steady-state wall-time of one full-participation DTFL round on the
    ``presets.table4_wall`` scenario (many small clients, width-4 / 8px
    micro ResNet — the regime the sequential loop's O(clients x batches)
    eager dispatches dominate; gradient quality is irrelevant here, table4's
    accuracy rows cover that). Warmup rounds absorb jit compilation and let
    the dynamic scheduler's assignments settle (observations are
    deterministic, so assignments — and with them the cohort shapes —
    stabilize after a few rounds)."""
    import jax

    from repro import presets

    fed = presets.table4_wall(n_clients, exec_mode=exec_mode,
                              devices=devices, chunk_size=chunk_size).build()
    tr = fed.trainer
    participants = list(range(n_clients))
    for r in range(warmup_rounds):
        tr.train_round(r, participants)
    # block: jax dispatch is async, so un-synced timings under-count device
    # work (PR 3 made this honest for every execution plane)
    jax.block_until_ready(tr.params)
    t0 = time.perf_counter()
    for r in range(warmup_rounds, warmup_rounds + timed_rounds):
        tr.train_round(r, participants)
        jax.block_until_ready(tr.params)
    return (time.perf_counter() - t0) / timed_rounds


def _population_rows(population: int, sample_size: int, chunk_size: int, *,
                     timed_rounds: int, warmup_rounds: int) -> list:
    """Round wall-time of the population regime: sample ``sample_size``
    clients per round from a ``population``-client lazy registry and train
    them chunked. Also reports how many registry slots actually
    materialized — the O(sample), not O(population), claim — and (stderr +
    info row) the process peak RSS, which stays flat as ``population``
    grows because never-sampled clients are a dict miss, not an object."""
    import resource

    import jax
    import numpy as np

    from repro import presets

    fed = presets.table4_population(
        population, sample_size=sample_size, chunk_size=chunk_size).build()
    tr = fed.trainer
    rng = np.random.default_rng(0)
    rounds = warmup_rounds + timed_rounds

    def sample(r):
        return sorted(rng.choice(population, sample_size, replace=False).tolist())

    for r in range(warmup_rounds):
        tr.train_round(r, sample(r))
    jax.block_until_ready(tr.params)
    t0 = time.perf_counter()
    for r in range(warmup_rounds, rounds):
        tr.train_round(r, sample(r))
        jax.block_until_ready(tr.params)
    wall = (time.perf_counter() - t0) / timed_rounds

    touched = tr.clients.n_touched
    limit = rounds * sample_size + 1  # +1: trainer ctor materializes client 0
    assert touched <= limit, (
        f"population regime leaked state: {touched} clients materialized "
        f"from a {population} registry after {rounds} rounds of "
        f"{sample_size} samples (limit {limit})")
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss // 1024
    print(f"table4_population: {touched}/{population} clients materialized, "
          f"peak rss {peak_mb} MB", file=sys.stderr)
    return [
        ("table4_population", population, sample_size, chunk_size,
         round(wall, 3), touched),
        ("table4_population_mem", population, peak_mb),
    ]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="simulated host devices for the sharded sweep")
    args = ap.parse_args()
    shard_devices = (2, 4)
    if args.devices and args.devices > 1:
        # must precede first jax backend init (all repro imports are lazy);
        # ensure_sim_devices dedupes the flag and validates the device count
        from repro.launch.mesh import ensure_sim_devices

        ensure_sim_devices(args.devices)
        # sweep up to (and including) the forced device count
        shard_devices = tuple(sorted(
            {d for d in (2, 4) if d < args.devices} | {args.devices}
        ))
    main(sizes=(10, 20, 50), wall_sizes=(10, 50, 100, 200, 500) if args.full
         else (10, 50, 100), shard_devices=shard_devices)
